"""The paper's experiment, in miniature: profile a Spark-MLlib-style job,
then run adaptive runs with Enel and Ellis, with a failure phase.

    PYTHONPATH=src python examples/enel_dataflow.py [--job kmeans] [--runs 6]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--job", default="kmeans",
                    choices=["lr", "mpc", "kmeans", "gbt"])
    ap.add_argument("--runs", type=int, default=6)
    ap.add_argument("--profiling", type=int, default=6)
    args = ap.parse_args()

    from repro.dataflow import JobExperiment, window_stats

    exp = JobExperiment(args.job, seed=0)
    print(f"profiling {args.profiling} runs ...")
    exp.profile(args.profiling)
    print(f"runtime target: {exp.target:.0f}s")
    for i in range(args.runs):
        anomalous = i >= args.runs - 2          # failure phase at the end
        st_e = exp.adaptive_run("enel", inject_failures=anomalous)
        st_l = exp.adaptive_run("ellis", inject_failures=anomalous)
        tag = "ANOMALOUS" if anomalous else "normal   "
        print(f"[{tag}] enel: rt={st_e.runtime:6.0f}s viol={st_e.violation:5.0f}s "
              f"scale-outs={st_e.scaleouts} | "
              f"ellis: rt={st_l.runtime:6.0f}s viol={st_l.violation:5.0f}s")
    ws = window_stats(exp.stats, 1, 10_000)
    print(f"overall: CVC mean={ws['cvc_mean']:.2f} "
          f"CVS mean={ws['cvs_mean']:.2f} min")


if __name__ == "__main__":
    main()
