"""Quickstart: build a reduced architecture, train a few steps, serve a
request wave, and ask Enel for a scale-out recommendation.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-0.6b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    from repro.configs import TRAIN_4K, get_config, smoke_config
    from repro.data.pipeline import DataConfig, global_batch
    from repro.models import init_model, param_count
    from repro.serve.engine import Request, ServeEngine
    from repro.train.optimizer import AdamWConfig
    from repro.train.train import init_train_state, make_train_step

    cfg = smoke_config(get_config(args.arch))
    print(f"arch={args.arch} (reduced: {param_count(cfg):,} params, "
          f"family={cfg.family})")

    # --- train a few steps on the deterministic synthetic stream
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    dcfg = DataConfig()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in global_batch(
            dcfg, cfg, TRAIN_4K, i, dp_size=TRAIN_4K.global_batch // 4,
            seq_len=64).items()}
        state, metrics = step(state, batch)
        print(f"step {i}: loss={float(metrics['loss']):.3f} "
              f"grad_norm={float(metrics['grad_norm']):.2f}")

    # --- serve a small request wave
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        eng = ServeEngine(cfg, state["params"], max_len=64)
        reqs = [Request(prompt=np.arange(6) + 2, max_new_tokens=8)]
        stats = eng.serve_wave(reqs)
        print(f"served: {reqs[0].out_tokens} "
              f"({stats.decode_tok_s:.1f} tok/s decode)")

    # --- Enel: one scale-out recommendation on a toy trained model
    from repro.core.graph import CTX_DIM, NodeAttrs, build_graph
    from repro.core.scaling import EnelScaler
    from repro.core.training import EnelTrainer

    rng = np.random.RandomState(0)
    trainer = EnelTrainer()
    scaler = EnelScaler(trainer, (4, 36), candidate_stride=4)

    def nodes(k, a, z, observe=True):
        out = []
        for i in range(3):
            ctx = np.tanh(np.random.RandomState(i).randn(CTX_DIM)).astype(np.float32)
            rt = 30.0 / z + 1.0 if observe else None
            met = np.array([0.5, 1 / z, 0.1, 0.1, 0.0], np.float32) if observe else None
            out.append(NodeAttrs(f"st{i}", ctx, met, a if i == 0 else z, z,
                                 1.0, rt))
        return out

    graphs = []
    for _ in range(6):
        for k in range(4):
            s = int(rng.choice([4, 8, 16, 32]))
            ns = nodes(k, s, s)
            graphs.append(build_graph(ns, [(0, 1), (1, 2)], k))
            scaler.record_component(k, ns, sum(n.runtime for n in ns))
    trainer.fit(graphs, steps=128, from_scratch=True)
    builder = lambda k, a, z, preds: build_graph(
        nodes(k, a, z, observe=False) + preds,
        [(0, 1), (1, 2)] + [(3 + j, 0) for j in range(len(preds))], k)
    s, total, _ = scaler.recommend(graph_builder=builder, next_comp=1,
                                   n_components=4, elapsed=5.0,
                                   current_scaleout=8, target_runtime=20.0)
    print(f"Enel recommendation: scale-out {s} "
          f"(predicted total {total:.1f}s vs target 20s)")


if __name__ == "__main__":
    main()
