"""End-to-end training driver: any assigned arch at a chosen scale, with
checkpoint/restart and deterministic data.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b \
        --preset tiny --steps 50
    # ~100M-param run (slow on CPU; the real target is the TPU mesh):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def preset_config(arch: str, preset: str):
    from repro.configs import get_config, smoke_config
    cfg = get_config(arch)
    if preset == "tiny":
        return smoke_config(cfg)
    if preset == "100m":
        return dataclasses.replace(
            smoke_config(cfg), name=cfg.name + "-100m",
            n_layers=max(4, 2 * cfg.layer_period), d_model=512, n_heads=8,
            n_kv_heads=4, d_head=64, d_ff=2048 if cfg.d_ff else 0,
            vocab_size=50304, raw_vocab_size=50304, remat="none")
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.configs import TRAIN_4K
    from repro.data.pipeline import DataConfig, global_batch
    from repro.models import param_count
    from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                        save_checkpoint)
    from repro.train.optimizer import AdamWConfig
    from repro.train.train import init_train_state, make_train_step

    cfg = preset_config(args.arch, args.preset)
    print(f"{cfg.name}: {param_count(cfg):,} params")
    opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    start = 0
    if args.resume and latest_step(args.ckpt) is not None:
        state, start, _ = restore_checkpoint(args.ckpt, state)
        print(f"resumed from step {start}")
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    dcfg = DataConfig()
    t0 = time.time()
    tokens_done = 0
    for i in range(start, args.steps):
        np_batch = global_batch(dcfg, cfg, TRAIN_4K, i,
                                dp_size=TRAIN_4K.global_batch // args.batch,
                                seq_len=args.seq)
        batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        state, metrics = step_fn(state, batch)
        tokens_done += args.batch * args.seq
        if i % 10 == 0 or i == args.steps - 1:
            jax.block_until_ready(metrics["loss"])
            tps = tokens_done / (time.time() - t0)
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} {tps:,.0f} tok/s")
        if (i + 1) % args.ckpt_every == 0:
            import numpy as np
            host = jax.tree_util.tree_map(np.asarray, state)
            save_checkpoint(args.ckpt, i + 1, host)
    print("done.")


if __name__ == "__main__":
    main()
