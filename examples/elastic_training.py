"""Flagship beyond-paper example: Enel as the elastic-scaling control plane
of a JAX training job — re-meshes DP at component boundaries and recovers
from a simulated worker-group failure via checkpoint/restart.

Run with fake devices (fresh process required — jax locks device count):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_training.py
"""
import dataclasses
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402


def main():
    from repro.configs import TRAIN_4K, get_config, smoke_config
    from repro.train.elastic import ElasticConfig, ElasticTrainer

    cfg = smoke_config(get_config("qwen3-0.6b"))
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=8)
    ecfg = ElasticConfig(
        target_runtime=120.0,
        n_components=5,
        steps_per_component=3,
        dp_choices=(2, 4, 8),
        ckpt_dir="/tmp/repro_elastic_example",
        fail_at_component=2,       # simulated worker-group loss
        seed=0,
    )
    print(f"devices: {len(jax.devices())}; dp choices {ecfg.dp_choices}")
    trainer = ElasticTrainer(cfg, shape, ecfg)
    result = trainer.run()
    print("dp trace:        ", result["dp_trace"])
    print("rescales:        ", result["n_rescales"])
    print("final step:      ", result["final_step"])
    print(f"elapsed {result['elapsed']:.1f}s vs target "
          f"{result['target']:.0f}s -> met={result['met_target']}")
    for log in trainer.logs:
        if log.failed:
            print(f"component {log.comp_idx}: FAILURE -> restored from "
                  f"checkpoint at dp={log.dp}")


if __name__ == "__main__":
    main()
