"""Batched serving example: prefill + lockstep decode over request waves.

    PYTHONPATH=src python examples/serve_lm.py --arch xlstm-350m --waves 2
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--waves", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.models import init_model
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config(get_config(args.arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=96)
    rng = np.random.RandomState(0)
    for w in range(args.waves):
        reqs = [Request(prompt=rng.randint(2, cfg.raw_vocab_size,
                                           rng.randint(4, 24)),
                        max_new_tokens=int(rng.randint(4, 12)))
                for _ in range(args.batch)]
        stats = eng.serve_wave(reqs)
        print(f"wave {w}: prefill {stats.prefill_s*1e3:.0f}ms, "
              f"{stats.tokens_out} tokens at {stats.decode_tok_s:.1f} tok/s")
        for i, r in enumerate(reqs):
            print(f"  req{i}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
