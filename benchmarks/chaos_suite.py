"""Chaos-suite benchmark: drive the fleet control plane through
controller-side fault plans and measure how much target compliance the
robustness machinery gives back.

For every ``chaos_*`` scenario (see ``repro.sim.scenarios``) the suite runs
the same fleet campaign as the scenario suite, but with faults aimed at the
CONTROLLER: poisoned observations, resident-cache bit-rot, NaN model
parameters, dispatch timeouts, and controller crashes recovered from
checkpoints.  A clean ``node_failure`` campaign (same environment, no
control-plane faults) is the reference.

Rows merged into ``BENCH_decision.json`` under ``"chaos"`` carry, per job:
compliance + violation severity (as in the scenario grid), plus the
fault-handling counters (fallback decisions, retries, breaker trips,
quarantined cache rows, poisoned fits, injected timeouts, restores).

Acceptance gates (exit 1 on violation):

* zero non-finite / out-of-range scale-out decisions under every fault plan
  (the guardrail + fallback contract);
* mean compliance under chaos within ``--max-degradation`` (default 0.10)
  of the clean reference;
* a campaign killed at crash rounds and restored from checkpoints
  reproduces the uninterrupted decision trace exactly (with model-poisoning
  chaos active);
* optional ``--budget-s`` wall-clock budget.

``--ci-smoke`` reduces to 2 chaos scenarios x 2 jobs plus the trace check.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List

import numpy as np

try:
    from benchmarks.fig5_timing import merge_bench_json, merge_latency_rows
except ImportError:                      # run as a script from benchmarks/
    from fig5_timing import merge_bench_json, merge_latency_rows
from repro import obs
from repro.sim.evaluate import (CHAOS_SCENARIOS, chaos_trace_identity,
                                run_chaos_campaign)

REFERENCE_SCENARIO = "node_failure"      # same environment, no chaos


def _compliance_by_job(rows: List[Dict]) -> Dict[str, float]:
    return {r["job"]: r["compliance"] for r in rows
            if r["job"] != "__fleet__" and "compliance" in r}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default=",".join(CHAOS_SCENARIOS))
    ap.add_argument("--jobs", default="lr,mpc,kmeans,gbt")
    ap.add_argument("--engine", default="batched")
    ap.add_argument("--profile-runs", type=int, default=3)
    ap.add_argument("--adaptive-runs", type=int, default=6)
    ap.add_argument("--max-degradation", type=float, default=0.10,
                    help="max allowed drop of mean compliance vs the "
                    "clean reference")
    ap.add_argument("--no-trace-check", dest="trace_check",
                    action="store_false", default=True)
    ap.add_argument("--budget-s", type=float, default=0.0,
                    help="fail (exit 1) if total wall time exceeds this")
    ap.add_argument("--ci-smoke", action="store_true",
                    help="reduced 2-scenario x 2-job suite")
    ap.add_argument("--flight-recorder-out", default="",
                    help="write the controller flight-recorder span ring "
                    "as JSONL to this path after the suite")
    ap.add_argument("--out", default="BENCH_decision.json")
    args = ap.parse_args(argv)
    t_start = time.time()

    if args.ci_smoke:
        scenario_names = ["chaos_model", "chaos_crashes"]
        job_keys = ["kmeans", "gbt"]
        adaptive, profile = 4, 2
    else:
        scenario_names = [s for s in args.scenarios.split(",") if s]
        job_keys = [j for j in args.jobs.split(",") if j]
        adaptive, profile = args.adaptive_runs, args.profile_runs

    failures: List[str] = []
    all_rows: List[Dict] = []

    ref_rows = run_chaos_campaign(REFERENCE_SCENARIO, job_keys,
                                  engine=args.engine, profile_runs=profile,
                                  adaptive_runs=adaptive)
    ref = _compliance_by_job(ref_rows)
    ref_mean = float(np.mean(list(ref.values())))
    all_rows.extend(ref_rows)
    print(f"chaos,reference={REFERENCE_SCENARIO},"
          f"compliance_mean={ref_mean:.2f}")

    for name in scenario_names:
        rows = run_chaos_campaign(name, job_keys, engine=args.engine,
                                  profile_runs=profile,
                                  adaptive_runs=adaptive)
        all_rows.extend(rows)
        comp = _compliance_by_job(rows)
        comp_mean = float(np.mean(list(comp.values())))
        bad = sum(r.get("nonfinite_decisions", 0) for r in rows)
        fleet = next(r for r in rows if r["job"] == "__fleet__")
        degr = ref_mean - comp_mean
        print(f"chaos,{name},compliance_mean={comp_mean:.2f},"
              f"degradation={degr:+.2f},"
              f"fallbacks={fleet['svc_fallback_decisions']},"
              f"retries={fleet['svc_retries']},"
              f"breaker_trips={fleet['svc_breaker_trips']},"
              f"quarantined={fleet['quarantined_rows']},"
              f"restores={fleet['restores']},"
              f"nonfinite={bad}")
        if bad:
            failures.append(f"{name}: {bad} non-finite/out-of-range "
                            "decisions escaped the guardrails")
        if degr > args.max_degradation:
            failures.append(
                f"{name}: mean compliance degraded {degr:.2f} "
                f"> {args.max_degradation:.2f} vs {REFERENCE_SCENARIO}")

    trace_ok = None
    if args.trace_check:
        trace_ok = chaos_trace_identity(
            job_keys=tuple(job_keys[:2]), adaptive_runs=min(adaptive, 4))
        print(f"chaos,trace_identity,ok={trace_ok}")
        if not trace_ok:
            failures.append("crash/restore campaign diverged from the "
                            "uninterrupted trace")

    # controller latency distributions (decision dispatch + fit) from the
    # metrics registry: fixed-bucket histograms -> p50/p95/p99/max rows
    lat_rows: List[Dict] = []
    if obs.enabled():
        lat_rows = [dict(r, source="chaos_suite")
                    for r in obs.registry().rows()
                    if r["kind"] == "histogram"]
        for r in lat_rows:
            if not r.get("count"):
                continue
            print(f"latency,{r['metric']},{r['labels']},"
                  f"n={r['count']},p50={r['p50'] * 1e3:.3f}ms,"
                  f"p95={r['p95'] * 1e3:.3f}ms,p99={r['p99'] * 1e3:.3f}ms,"
                  f"max={r['max'] * 1e3:.3f}ms")
    if args.flight_recorder_out:
        obs.recorder().to_jsonl(args.flight_recorder_out)
        print(f"flight recorder: {len(obs.recorder())} spans -> "
              f"{os.path.abspath(args.flight_recorder_out)}")

    wall = time.time() - t_start
    summary = {"job": "__suite__", "reference": REFERENCE_SCENARIO,
               "reference_compliance_mean": ref_mean,
               "scenarios": scenario_names, "jobs": job_keys,
               "adaptive_runs": adaptive, "trace_identity": trace_ok,
               "wall_s": wall, "failures": failures}
    merge_bench_json(args.out, {"chaos": all_rows + [summary]})
    if lat_rows:
        merge_latency_rows(args.out, lat_rows, "chaos_suite")
    print(f"wrote {os.path.abspath(args.out)} (total {wall:.0f}s)")
    if args.budget_s and wall > args.budget_s:
        failures.append(f"chaos suite took {wall:.0f}s "
                        f"> budget {args.budget_s:.0f}s")
    for f in failures:
        print(f"FAIL: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
