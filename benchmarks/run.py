"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  The heavyweight Enel-vs-Ellis
campaign (Table III / Fig. 4) runs at reduced scale here by default and is
cached under artifacts/experiments; the full 55-run campaign used for
EXPERIMENTS.md is produced by ``python -m benchmarks.table3_prediction``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _bench(name: str, fn, derived_fn=lambda r: "ok"):
    t0 = time.time()
    try:
        res = fn()
        us = (time.time() - t0) * 1e6
        print(f"{name},{us:.0f},{derived_fn(res)}")
        return True
    except Exception as e:  # report and continue
        us = (time.time() - t0) * 1e6
        print(f"{name},{us:.0f},ERROR:{type(e).__name__}:{e}")
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 55-adaptive-run campaign (slow)")
    args, _ = ap.parse_known_args()
    # prefer already-cached full campaigns (artifacts/experiments)
    from benchmarks.experiment import campaign_path
    cached55 = [j for j in ("lr", "mpc", "kmeans", "gbt")
                if campaign_path(j, "enel", 55).exists()
                and campaign_path(j, "ellis", 55).exists()]
    if args.full or len(cached55) >= 2:
        n_adaptive, camp_jobs = 55, (cached55 or ["kmeans", "gbt"])
    else:
        n_adaptive, camp_jobs = 15, ["kmeans", "gbt"]
    ok = True

    # Table II: jobs + datasets ground truth
    def table2():
        from repro.dataflow.workloads import JOBS, make_multiclass
        x, _ = make_multiclass(512)
        return {j.name: round(j.base_runtime(16), 1) for j in JOBS.values()}
    ok &= _bench("table2_jobs_base_runtime_s", table2, lambda r: str(r))

    # Table III: CVC/CVS windows, Enel vs Ellis (kmeans+gbt in fast mode)
    def table3():
        from benchmarks.table3_prediction import run
        t = run(jobs=camp_jobs, n_adaptive=n_adaptive)
        last = {f"{k[0]}/{k[1]}": round(v[-1]["cvc_mean"], 2)
                for k, v in t.items()}
        return last
    ok &= _bench("table3_cvc_final_window", table3, lambda r: str(r))

    # Fig 4: adaptive behaviour incl. failure phases
    def fig4():
        from benchmarks.fig4_adaptive import summarize
        out = {}
        for j in camp_jobs:
            s = summarize(j, n_adaptive)
            out[j] = round(s["enel"]["viol_second_half"] -
                           s["enel"]["viol_first_half"], 1)
        return out
    ok &= _bench("fig4_violation_improvement_s", fig4, lambda r: str(r))

    # Fig 5: fine-tune / inference timing
    def fig5():
        from benchmarks.fig5_timing import measure
        rows = [measure(j, repeats=5) for j in ("lr", "gbt")]
        return {r["job"]: round(r["fit_s_median"], 2) for r in rows}
    ok &= _bench("fig5_finetune_seconds", fig5, lambda r: str(r))

    # Roofline table + hillclimb-cell selection (reads dry-run artifacts)
    def roofline():
        from benchmarks.roofline import load_all, pick_hillclimb_cells
        rows = [r for r in load_all("pod1") if r.get("status") == "ok"]
        cells = pick_hillclimb_cells()
        return {"cells": len(rows),
                "picked": {k: f"{v['arch']}--{v['shape']}"
                           for k, v in cells.items()}}
    ok &= _bench("roofline_table", roofline, lambda r: str(r))

    # Kernel + smoke-train microbenches
    def micro():
        from benchmarks.microbench import kernel_benches, train_step_benches
        rows = kernel_benches() + train_step_benches()
        for r in rows:
            print(f"{r['name']},{r['us']:.0f},interpret_or_smoke")
        return len(rows)
    ok &= _bench("microbench_suite", micro, lambda r: f"{r}_benches")

    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
