"""Shared driver for the paper's evaluation protocol (feeds Table III, Fig. 4
and Fig. 5 benchmarks).  Results are cached as JSON so the heavyweight
adaptive-run campaign executes once."""
from __future__ import annotations

import json
import time
from dataclasses import asdict
from pathlib import Path
from typing import Dict, List

from repro.dataflow import JobExperiment, window_stats

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"

# adaptive-run phases (paper Fig. 4: alternating normal / anomalous)
def phase_plan(n_adaptive: int) -> List[bool]:
    """True = anomalous (failure-injected) run."""
    plan = []
    for i in range(n_adaptive):
        frac = i / max(n_adaptive - 1, 1)
        plan.append(0.28 <= frac < 0.45 or 0.64 <= frac < 0.82)
    return plan


def run_job_campaign(job_key: str, method: str, *, n_profiling: int = 10,
                     n_adaptive: int = 55, seed: int = 0) -> Dict:
    exp = JobExperiment(job_key, seed=seed)
    exp.profile(n_profiling)
    plan = phase_plan(n_adaptive)
    runs = []
    for i, anomalous in enumerate(plan):
        st = exp.adaptive_run(method, inject_failures=anomalous)
        runs.append({**{k: v for k, v in asdict(st).items()},
                     "anomalous": anomalous})
    return {"job": job_key, "method": method, "target": exp.target,
            "n_profiling": n_profiling, "runs": runs}


def campaign_path(job_key: str, method: str, n_adaptive: int) -> Path:
    return ARTIFACTS / "experiments" / f"{job_key}--{method}--{n_adaptive}.json"


def get_or_run(job_key: str, method: str, *, n_profiling: int = 10,
               n_adaptive: int = 55, seed: int = 0, verbose: bool = True
               ) -> Dict:
    p = campaign_path(job_key, method, n_adaptive)
    if p.exists():
        return json.loads(p.read_text())
    t0 = time.time()
    res = run_job_campaign(job_key, method, n_profiling=n_profiling,
                           n_adaptive=n_adaptive, seed=seed)
    res["wall_seconds"] = time.time() - t0
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(res))
    if verbose:
        print(f"[experiment] {job_key}/{method}/{n_adaptive}: "
              f"{res['wall_seconds']:.0f}s")
    return res


def windows(n_profiling: int, n_adaptive: int, k: int = 5):
    """k equal run-index windows over the adaptive range (Table III style)."""
    lo = n_profiling + 1
    hi = n_profiling + n_adaptive
    edges = [lo + round(i * (hi - lo + 1) / k) for i in range(k)] + [hi + 1]
    return [(edges[i], edges[i + 1] - 1) for i in range(k)]


def campaign_window_stats(res: Dict, k: int = 5) -> List[Dict]:
    import numpy as np
    out = []
    for (lo, hi) in windows(res["n_profiling"], len(res["runs"]), k):
        sel = [r for r in res["runs"] if lo <= r["run_idx"] <= hi]
        cvc = np.array([r["violation"] > 0 for r in sel], float)
        cvs = np.array([r["violation"] / 60.0 for r in sel], float)
        out.append({"window": f"{lo}-{hi}",
                    "cvc_mean": float(cvc.mean()) if len(sel) else float("nan"),
                    "cvc_median": float(np.median(cvc)) if len(sel) else float("nan"),
                    "cvs_mean": float(cvs.mean()) if len(sel) else float("nan"),
                    "cvs_median": float(np.median(cvs)) if len(sel) else float("nan")})
    return out
