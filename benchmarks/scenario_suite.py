"""Scenario-suite benchmark: disturbance grid, cross-context transfer cells,
and the sim-engine throughput race.

Three measurements, all merged into ``BENCH_decision.json``:

* ``scenarios`` — every scenario x job cell through a FleetCampaign
  (vectorized engine, cross-batched decisions): per-scenario
  target-compliance, violation severity, rescale counts, fleet
  decisions/sec.  The ``multi_tenant`` scenario runs the Poisson-arrival
  capacity campaign (capacity-capped picks against a bounded pool).
* ``scenario_transfer`` — train the model under context A (scenario,
  dataset size), deploy under context B without a scratch retrain; per-cell
  compliance + prediction error of the reused model (paper §I/§VI reuse
  claim).
* ``sim_engine`` — fleet-of-N end-to-end simulation campaign wall time:
  the numpy per-job event loop vs the vectorized engine (per-component
  lockstep steps AND whole-run single dispatches), median-of-k with IQR.
* ``fused_race`` — the fleet-32 acceptance race for the whole-campaign
  kernel (``core/campaign_kernel.py``): sim step + decision sweep +
  resident fit fused into ONE scanned jit vs the stepped python loop over
  the same jitted body.  Bit-exact traces (tests/test_fused_campaign.py),
  so the race is pure host-dispatch overhead; plan build (host-side, once
  per campaign) is timed separately.

``--ci-smoke`` runs a reduced 2-scenario x 2-job suite plus a small engine
race under a wall-clock budget (exit 1 on overrun) so CI guards both the
subsystem's health and its cost.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List

import numpy as np

try:
    from benchmarks.fig5_timing import med_iqr, merge_bench_json
except ImportError:                      # run as a script from benchmarks/
    from fig5_timing import med_iqr, merge_bench_json
from repro.dataflow.workloads import JOBS
from repro.sim.engine import (BatchedClusterSim, NumpySimBackend,
                              SimStepRequest)
from repro.sim.evaluate import (DEFAULT_JOBS, DEFAULT_SCENARIOS,
                                DEFAULT_TRANSFER_CELLS,
                                run_scenario_campaign, run_transfer_cells)
from repro.sim.scenarios import make_scenario

JOB_CYCLE = ("lr", "mpc", "kmeans", "gbt")


# ------------------------------------------------------------ engine race
def measure_engine(fleet_size: int = 32, runs: int = 2, repeats: int = 5,
                   scenario_name: str = "node_failure", seed: int = 0
                   ) -> Dict:
    """End-to-end wall time of a fleet simulation campaign (records
    materialized, failure injection on) under three engines:

    * ``numpy``: the per-job event loop (reference),
    * ``batched_step``: vectorized engine, one dispatch per fleet
      component-step (the adaptive-campaign access pattern),
    * ``batched_full``: vectorized engine, one dispatch per full fleet run
      (the profiling / scenario-replay access pattern).

    All three replay the same seeded rescale schedules; the batched paths
    are bit-identical to the numpy loop (asserted in tests), so this is a
    pure wall-clock race.
    """
    sc = make_scenario(scenario_name, seed=seed)
    jobs = [JOBS[JOB_CYCLE[i % len(JOB_CYCLE)]] for i in range(fleet_size)]
    c_max = max(j.n_components for j in jobs)
    rng = np.random.RandomState(seed)
    scheds = [(rng.choice([8, 16, 24, 32], (fleet_size, c_max)).astype(int),
               rng.choice([8, 16, 24, 32], (fleet_size, c_max)).astype(int))
              for _ in range(runs)]

    npb = NumpySimBackend()
    stepped = BatchedClusterSim()
    full = BatchedClusterSim()
    for i, job in enumerate(jobs):
        npb.register(job, seed=seed + i, scenario=sc)
        stepped.register(job, seed=seed + i, scenario=sc)
        full.register(job, seed=seed + i, scenario=sc)

    def campaign_numpy():
        for a, z in scheds:
            for j, job in enumerate(jobs):
                npb.begin_run(j)
                clock = 0.0
                for k in range(job.n_components):
                    r = npb.step([SimStepRequest(j, k, int(a[j, k]),
                                                 int(z[j, k]), clock,
                                                 True)])[0]
                    clock = r.clock_end

    def campaign_stepped():
        for a, z in scheds:
            clocks = [0.0] * fleet_size
            for j in range(fleet_size):
                stepped.begin_run(j)
            for k in range(c_max):
                reqs = [SimStepRequest(j, k, int(a[j, k]), int(z[j, k]),
                                       clocks[j], True)
                        for j, job in enumerate(jobs)
                        if k < job.n_components]
                for req, res in zip(reqs, stepped.step(reqs)):
                    clocks[req.slot] = res.clock_end

    def campaign_full():
        for a, z in scheds:
            full.run_full(a, z, inject_failures=True)

    times = {"numpy": [], "batched_step": [], "batched_full": []}
    fns = {"numpy": campaign_numpy, "batched_step": campaign_stepped,
           "batched_full": campaign_full}
    for name, fn in fns.items():
        fn()                                  # warmup (jit compile)
        for _ in range(repeats):
            t0 = time.time()
            fn()
            times[name].append(time.time() - t0)
    row = {"fleet_size": fleet_size, "runs_per_campaign": runs,
           "scenario": scenario_name, "repeats": repeats}
    for name in fns:
        m = med_iqr(times[name])
        row[f"{name}_s_median"] = m["median"]
        row[f"{name}_s_iqr"] = m["iqr"]
    row["speedup_step"] = row["numpy_s_median"] / row["batched_step_s_median"]
    row["speedup_full"] = row["numpy_s_median"] / row["batched_full_s_median"]
    return row


# ------------------------------------------------------------- fused race
def measure_fused_race(fleet_size: int = 32, runs: int = 2,
                       repeats: int = 5, scenario_name: str = "node_failure",
                       seed: int = 40, profile_runs: int = 3) -> Dict:
    """Fused whole-campaign scan vs the STEPPED PATH under the disturbance
    scenario the acceptance gate names.

    ``speedup_fused`` (the gated number) races against the live stepped
    driver — ``adaptive_campaign`` on a fresh twin fleet per repeat: host
    python graph building, per-bucket service dispatch and sequential
    per-job resident fits, i.e. exactly the host round-trips fusion
    removes.  ``speedup_vs_twin`` is the secondary dispatch-overhead-only
    number against the python loop over the fused plan's own jitted step
    body (bit-exact twin).  One seed per job class so the plan dedups to 4
    structural classes; plan build is reported separately (host-side, once
    per campaign, amortized over every run it drives)."""
    import jax

    from repro.core import campaign_kernel as ck
    from repro.core.service import DecisionService
    from repro.dataflow import FleetCampaign, JobExperiment

    def fresh_fleet() -> FleetCampaign:
        exps = [JobExperiment(JOB_CYCLE[i % len(JOB_CYCLE)],
                              seed=seed + i % len(JOB_CYCLE),
                              scenario=make_scenario(scenario_name,
                                                     seed=seed))
                for i in range(fleet_size)]
        camp = FleetCampaign(exps, DecisionService(), engine="batched")
        camp.profile(profile_runs)
        return camp

    camp = fresh_fleet()
    t0 = time.time()
    plan = ck.build_plan(camp.experiments, runs)
    plan_build_s = time.time() - t0
    c_f, ys_f = ck.run_fused(plan)            # warmup: compiles the scan
    jax.block_until_ready(ys_f)
    _, ys_s = ck.run_stepped(plan)            # warmup: compiles the step
    jax.block_until_ready(ys_s)
    fused_t, twin_t = [], []
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(ck.run_fused(plan)[1])
        fused_t.append(time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(ck.run_stepped(plan)[1])
        twin_t.append(time.time() - t0)
    live_t = []
    for _ in range(min(repeats, 3)):      # fresh fleet per repeat: the
        twin = fresh_fleet()              # scratch/tune fit cadence then
        t0 = time.time()                  # matches the fused plan's
        twin.adaptive_campaign(runs)
        live_t.append(time.time() - t0)
    fm, tm, lm = med_iqr(fused_t), med_iqr(twin_t), med_iqr(live_t)
    return {"fleet_size": fleet_size, "runs_per_campaign": runs,
            "scenario": scenario_name, "repeats": repeats,
            "steps": plan.n_steps, "plan_build_s": plan_build_s,
            "fused_s_median": fm["median"], "fused_s_iqr": fm["iqr"],
            "stepped_s_median": lm["median"], "stepped_s_iqr": lm["iqr"],
            "twin_s_median": tm["median"], "twin_s_iqr": tm["iqr"],
            "speedup_fused": lm["median"] / fm["median"],
            "speedup_vs_twin": tm["median"] / fm["median"],
            "nonfinite_decisions": int(np.asarray(c_f["nonfinite"]).sum())}


# ----------------------------------------------------------------- driver
def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS) +
                    ",multi_tenant")
    ap.add_argument("--jobs", default=",".join(DEFAULT_JOBS))
    ap.add_argument("--engine", default="batched")
    ap.add_argument("--profile-runs", type=int, default=3)
    ap.add_argument("--adaptive-runs", type=int, default=3)
    ap.add_argument("--transfer", action="store_true", default=True)
    ap.add_argument("--no-transfer", dest="transfer", action="store_false")
    ap.add_argument("--fleet", type=int, default=32)
    ap.add_argument("--engine-runs", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--fused-runs", type=int, default=2)
    ap.add_argument("--fused-min-speedup", type=float, default=0.0,
                    help="fail (exit 1) if the fused race speedup over the "
                         "stepped loop drops below this (acceptance: 3.0 "
                         "on an idle machine; leave 0 in CI — timings "
                         "there are noise)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    default=True)
    ap.add_argument("--budget-s", type=float, default=0.0,
                    help="fail (exit 1) if total wall time exceeds this")
    ap.add_argument("--ci-smoke", action="store_true",
                    help="reduced 2x2 suite + small engine race")
    ap.add_argument("--out", default="BENCH_decision.json")
    args = ap.parse_args(argv)
    t_start = time.time()

    if args.ci_smoke:
        scenario_names = ["node_failure", "multi_tenant"]
        job_keys = ["kmeans", "gbt"]
        transfer_cells = DEFAULT_TRANSFER_CELLS[:1]
        fleet, adaptive, profile = 8, 1, 2
    else:
        scenario_names = [s for s in args.scenarios.split(",") if s]
        job_keys = [j for j in args.jobs.split(",") if j]
        transfer_cells = DEFAULT_TRANSFER_CELLS if args.transfer else ()
        fleet, adaptive, profile = (args.fleet, args.adaptive_runs,
                                    args.profile_runs)

    scenario_rows: List[Dict] = []
    for name in scenario_names:
        rows = run_scenario_campaign(name, job_keys, engine=args.engine,
                                     profile_runs=profile,
                                     adaptive_runs=adaptive)
        scenario_rows.extend(rows)
        for r in rows:
            if r["job"] == "__fleet__":
                print(f"scenario,{name},fleet={r['fleet_size']},"
                      f"decisions={r.get('decisions', 0)},"
                      f"dec_per_s={r.get('decisions_per_s', 0):.1f}"
                      + (f",capped={r['capped_decisions']}"
                         if "capped_decisions" in r else ""))
            else:
                print(f"scenario,{name},{r['job']},"
                      f"compliance={r.get('compliance', float('nan')):.2f},"
                      f"cvs={r.get('cvs_mean_min', float('nan')):.2f}min,"
                      f"rescales={r.get('rescales_mean', float('nan')):.1f}")

    transfer_rows: List[Dict] = []
    if transfer_cells:
        transfer_rows = run_transfer_cells(transfer_cells,
                                           engine=args.engine,
                                           adaptive_runs=adaptive + 1)
        for r in transfer_rows:
            print(f"transfer,{r['train_scenario']}@{r['train_size']}->"
                  f"{r['deploy_scenario']}@{r['deploy_size']},{r['job']},"
                  f"compliance={r.get('compliance', float('nan')):.2f},"
                  f"pred_err={r.get('pred_rel_err_mean', float('nan')):.2f}")

    engine_row = measure_engine(fleet_size=fleet, runs=args.engine_runs,
                                repeats=max(args.repeats, 5))
    print(f"sim_engine,fleet={engine_row['fleet_size']},"
          f"numpy={engine_row['numpy_s_median']*1e3:.0f}ms,"
          f"step={engine_row['batched_step_s_median']*1e3:.0f}ms,"
          f"full={engine_row['batched_full_s_median']*1e3:.0f}ms,"
          f"speedup_step={engine_row['speedup_step']:.1f}x,"
          f"speedup_full={engine_row['speedup_full']:.1f}x")

    ok = True
    updates = {"scenarios": scenario_rows,
               "scenario_transfer": transfer_rows,
               "sim_engine": [engine_row]}
    if args.fused:
        fused_row = measure_fused_race(fleet_size=fleet,
                                       runs=args.fused_runs,
                                       repeats=max(args.repeats, 5))
        print(f"fused_race,fleet={fused_row['fleet_size']},"
              f"fused={fused_row['fused_s_median']*1e3:.0f}ms,"
              f"stepped={fused_row['stepped_s_median']*1e3:.0f}ms,"
              f"twin={fused_row['twin_s_median']*1e3:.0f}ms,"
              f"plan_build={fused_row['plan_build_s']:.1f}s,"
              f"speedup_fused={fused_row['speedup_fused']:.1f}x,"
              f"vs_twin={fused_row['speedup_vs_twin']:.2f}x")
        updates["fused_race"] = [fused_row]
        if fused_row["nonfinite_decisions"]:
            print(f"FAIL: fused race produced "
                  f"{fused_row['nonfinite_decisions']} non-finite decisions")
            ok = False
        if (args.fused_min_speedup and
                fused_row["speedup_fused"] < args.fused_min_speedup):
            print(f"FAIL: fused speedup {fused_row['speedup_fused']:.1f}x "
                  f"< required {args.fused_min_speedup:.1f}x")
            ok = False

    merge_bench_json(args.out, updates)
    wall = time.time() - t_start
    print(f"wrote {os.path.abspath(args.out)} (total {wall:.0f}s)")
    if args.budget_s and wall > args.budget_s:
        print(f"FAIL: scenario suite took {wall:.0f}s "
              f"> budget {args.budget_s:.0f}s")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
