"""Fig. 5 analogue: time to fine-tune an Enel model and run inference, per
job class (GBT decomposes into more components -> more graphs -> longer)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.dataflow import JOBS, JobExperiment


def measure(job_key: str, seed: int = 0, repeats: int = 3) -> Dict:
    exp = JobExperiment(job_key, seed=seed)
    exp.profile(4)
    fit_times, pred_times = [], []
    n_comp = exp.job.n_components
    for _ in range(repeats):
        t0 = time.time()
        exp.trainer.fit(exp.graph_history[-n_comp:], steps=60)
        fit_times.append(time.time() - t0)
        graphs = exp.graph_history[-n_comp:]
        t0 = time.time()
        exp.trainer.predict(graphs)
        pred_times.append(time.time() - t0)
    return {"job": job_key, "n_graphs": n_comp,
            "fit_s_mean": float(np.mean(fit_times)),
            "fit_s_std": float(np.std(fit_times)),
            "predict_s_mean": float(np.mean(pred_times))}


def main():
    rows = []
    for job in ("lr", "mpc", "kmeans", "gbt"):
        r = measure(job)
        rows.append(r)
        print(f"fig5,{job},graphs={r['n_graphs']},fit={r['fit_s_mean']:.2f}s,"
              f"predict={r['predict_s_mean']:.3f}s")
    return rows


if __name__ == "__main__":
    main()
