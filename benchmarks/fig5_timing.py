"""Fig. 5 analogue: time to fine-tune an Enel model and run inference, per
job class (GBT decomposes into more components -> more graphs -> longer),
plus the scale-out *decision* latency: the per-candidate graph-construction
path (``EnelScaler.recommend_pergraph``) vs. the batched template+delta
sweep (``EnelScaler.recommend``), plus the *fit* latency: the legacy
restack-per-call path (``EnelTrainer.fit``) vs. the device-resident
ring-buffer fast path (``EnelTrainer.fit_resident``) the runner now uses.
Emits ``BENCH_decision.json`` so the decision- and fit-latency trajectories
are tracked across PRs (CI uploads the JSON as an artifact).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.dataflow import JOBS, JobExperiment
from repro.dataflow.runner import HISTORY_WINDOW


def merge_bench_json(out_path: str, updates: Dict) -> None:
    """Merge section rows into the benchmark JSON without clobbering other
    writers' sections (fig5/fit/decision here vs fleet/fleet_budget from
    ``benchmarks/fleet_bench.py`` vs the scenario-suite sections).

    numpy scalars that leak into rows (e.g. an np.float32 simulator stat)
    coerce via ``float``; arrays still fail loudly."""
    data = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data.update(updates)
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2, default=float)


def merge_latency_rows(out_path: str, rows, source: str) -> None:
    """Merge controller latency-histogram rows into the shared ``latency``
    section by writer ``source``: this writer's previous rows are replaced,
    other writers' rows (fleet_bench vs chaos_suite) are kept."""
    prev = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            prev = [r for r in json.load(f).get("latency", [])
                    if r.get("source") != source]
    merge_bench_json(out_path, {"latency": prev + list(rows)})


def med_iqr(xs) -> Dict[str, float]:
    """CPU wall timings here are noisy (see CI flakes): report the median
    of k >= 5 repeats with the interquartile range instead of mean/std,
    which a single straggler repeat can dominate."""
    q1, med, q3 = np.percentile(xs, [25, 50, 75])
    return {"median": float(med), "iqr": float(q3 - q1)}


def measure(job_key: str, seed: int = 0, repeats: int = 5) -> Dict:
    """fit here is the runner's actual online path: a resident fine-tune on
    the newest run's graphs (same content the legacy row restacked).

    Deliberately NO warmup, matching how the historical fig5 rows were
    taken: the first repeat carries any one-off jit compile — which is
    exactly why these rows are medians: the median of k >= 5 repeats sits
    in the warmed steady state while the IQR exposes the compile outlier."""
    exp = JobExperiment(job_key, seed=seed)
    exp.profile(4)
    fit_times, pred_times = [], []
    n_comp = exp.job.n_components
    for _ in range(repeats):
        t0 = time.time()
        exp.trainer.fit_resident(steps=60, latest_only=True)
        fit_times.append(time.time() - t0)
        graphs = exp.graph_history[-n_comp:]
        t0 = time.time()
        exp.trainer.predict(graphs)
        pred_times.append(time.time() - t0)
    fit, pred = med_iqr(fit_times), med_iqr(pred_times)
    return {"job": job_key, "n_graphs": n_comp,
            "fit_s_median": fit["median"], "fit_s_iqr": fit["iqr"],
            "predict_s_median": pred["median"],
            "predict_s_iqr": pred["iqr"]}


def measure_fit(job_key: str, seed: int = 0, repeats: int = 5) -> Dict:
    """Legacy vs fast fit path, fine-tune (60 steps on the newest run) and
    scratch retrain (160 steps on the history window).  Every path gets one
    untimed warmup call first so the rows compare steady-state latency —
    the resident scratch jit is already warm from profile()'s initial fit,
    and leaving the others cold would bill their one-off compiles to the
    legacy medians only.  Timings are median-of-k with IQR (k >= 5)."""
    exp = JobExperiment(job_key, seed=seed)
    exp.profile(4)
    n_comp = exp.job.n_components

    def timed(fn):
        fn()                                   # warmup (jit compile)
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            fn()
            ts.append(time.time() - t0)
        m = med_iqr(ts)
        return m["median"], m["iqr"]

    leg_ft, leg_ft_iqr = timed(
        lambda: exp.trainer.fit(exp.graph_history[-n_comp:], steps=60))
    res_ft, res_ft_iqr = timed(
        lambda: exp.trainer.fit_resident(steps=60, latest_only=True))
    leg_sc, _ = timed(lambda: exp.trainer.fit(
        exp.graph_history[-HISTORY_WINDOW:], steps=160, from_scratch=True))
    res_sc, _ = timed(
        lambda: exp.trainer.fit_resident(steps=160, from_scratch=True))
    return {"job": job_key, "n_graphs": n_comp,
            "finetune_s_legacy": leg_ft, "finetune_s_legacy_iqr": leg_ft_iqr,
            "finetune_s_resident": res_ft,
            "finetune_s_resident_iqr": res_ft_iqr,
            "finetune_speedup": leg_ft / max(res_ft, 1e-9),
            "scratch_s_legacy": leg_sc, "scratch_s_resident": res_sc,
            "scratch_speedup": leg_sc / max(res_sc, 1e-9)}


def measure_decision(job_key: str, seed: int = 0, repeats: int = 5) -> Dict:
    """recommend() decision latency: per-candidate path vs. batched sweep.

    Reproduces the runner's mid-run decision context (component 0 finished,
    all others remaining — the largest sweep of the job) and times both
    engines after jit warmup.  Also records the worst per-component deviation
    between the batched sweep and per-graph predictions of the SAME
    template-derived graphs (materialized host-side per candidate).
    """
    from repro.core import model as enel_model
    from repro.core.graph import materialize_candidate, summary_node
    from repro.dataflow.runner import (_component_nodes, _future_nodes,
                                       _to_graph)

    traces0 = (enel_model.trace_count("sweep_per_component") +
               enel_model.trace_count("fleet_sweep"))
    exp = JobExperiment(job_key, seed=seed)
    exp.profile(4)
    job = exp.job
    builder = lambda ci, a, z, pr: _to_graph(
        _future_nodes(exp.encoder, job, ci, a, z), pr, ci)
    comp = exp.sim.run_component(job, 0, clock=0.0, start_scaleout=8,
                                 end_scaleout=8, inject_failures=False,
                                 failures_log=[])
    summ = summary_node(_component_nodes(exp.encoder, job, comp), name="P0")
    kw = dict(graph_builder=builder, next_comp=1,
              n_components=job.n_components, elapsed=comp.runtime,
              current_scaleout=8, target_runtime=exp.target,
              current_summary=summ)

    # numerical parity of the batching itself: batched sweep vs per-graph
    # predict on IDENTICAL template-materialized graphs (isolates the jit
    # batching; context-freezing semantics are shared by both sides here)
    cands = exp.enel.candidate_scaleouts(8)
    template, deltas = exp.enel.build_sweep(
        graph_builder=builder, next_comp=1, n_components=job.n_components,
        current_scaleout=8, candidates=cands, current_summary=summ)
    per = exp.enel.trainer.predict_sweep(template, deltas)
    max_dev = 0.0
    for c in range(len(cands)):
        ref = exp.enel.trainer.predict_stacked(
            materialize_candidate(template, deltas, c))
        max_dev = max(max_dev, float(np.abs(ref - per[c]).max()))

    # end-to-end divergence vs the legacy engine (includes the deliberate
    # candidate-invariant-context modeling difference + encoder RNG draws)
    _, _, tot_b = exp.enel.recommend(**kw)
    _, _, tot_p = exp.enel.recommend_pergraph(**kw)
    rel_gap = max(abs(tot_b[s] - tot_p[s]) / max(abs(tot_p[s]), 1e-9)
                  for s in tot_b)

    timings, iqrs = {}, {}
    for name, fn in (("batched", exp.enel.recommend),
                     ("pergraph", exp.enel.recommend_pergraph)):
        fn(**kw)                                   # warmup (jit compile)
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            fn(**kw)
            ts.append(time.time() - t0)
        m = med_iqr(ts)
        timings[name], iqrs[name] = m["median"], m["iqr"]
    return {"job": job_key, "n_components": job.n_components,
            "n_candidates": len(cands),
            "n_graphs_per_decision": len(cands) * (job.n_components - 1),
            "decide_ms_pergraph": timings["pergraph"] * 1e3,
            "decide_ms_pergraph_iqr": iqrs["pergraph"] * 1e3,
            "decide_ms_batched": timings["batched"] * 1e3,
            "decide_ms_batched_iqr": iqrs["batched"] * 1e3,
            "speedup": timings["pergraph"] / timings["batched"],
            "max_abs_dev_sweep_vs_materialized": max_dev,
            "max_rel_total_gap_vs_legacy_engine": rel_gap,
            # sweep-jit compiles this job's decision context cost (warmup
            # included) — the compile-amortization axis of the perf story
            "decide_recompiles":
                enel_model.trace_count("sweep_per_component") +
                enel_model.trace_count("fleet_sweep") - traces0}


def main(out_path: str = "BENCH_decision.json"):
    rows = []
    for job in ("lr", "mpc", "kmeans", "gbt"):
        r = measure(job)
        rows.append(r)
        print(f"fig5,{job},graphs={r['n_graphs']},"
              f"fit={r['fit_s_median']:.2f}s±{r['fit_s_iqr']:.2f},"
              f"predict={r['predict_s_median']:.3f}s")
    fit_rows = []
    for job in ("lr", "mpc", "kmeans", "gbt"):
        r = measure_fit(job)
        fit_rows.append(r)
        print(f"fit,{job},graphs={r['n_graphs']},"
              f"legacy={r['finetune_s_legacy']:.2f}s,"
              f"resident={r['finetune_s_resident']:.2f}s,"
              f"speedup={r['finetune_speedup']:.1f}x,"
              f"scratch_legacy={r['scratch_s_legacy']:.2f}s,"
              f"scratch_resident={r['scratch_s_resident']:.2f}s,"
              f"scratch_speedup={r['scratch_speedup']:.1f}x")
    decision_rows = []
    for job in ("lr", "mpc", "kmeans", "gbt"):
        d = measure_decision(job)
        decision_rows.append(d)
        print(f"decision,{job},cands={d['n_candidates']},"
              f"pergraph={d['decide_ms_pergraph']:.1f}ms,"
              f"batched={d['decide_ms_batched']:.1f}ms,"
              f"speedup={d['speedup']:.1f}x,"
              f"max_dev={d['max_abs_dev_sweep_vs_materialized']:.2e},"
              f"legacy_gap={d['max_rel_total_gap_vs_legacy_engine']:.3f},"
              f"recompiles={d['decide_recompiles']}")
    merge_bench_json(out_path, {"fig5": rows, "fit": fit_rows,
                                "decision": decision_rows})
    print(f"wrote {os.path.abspath(out_path)}")
    return rows, fit_rows, decision_rows


if __name__ == "__main__":
    main()
