"""Fig. 4 analogue: per-run runtimes vs target across the adaptive campaign
(with anomalous phases marked) — ASCII rendering + summary stats."""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.experiment import get_or_run


def summarize(job: str, n_adaptive: int = 55, seed: int = 0) -> Dict:
    out = {}
    for method in ("enel", "ellis"):
        res = get_or_run(job, method, n_adaptive=n_adaptive, seed=seed)
        runs = res["runs"]
        normal = [r for r in runs if not r["anomalous"]]
        anom = [r for r in runs if r["anomalous"]]
        halves = np.array_split([r["violation"] for r in runs], 2)
        out[method] = {
            "target": res["target"],
            "viol_normal_mean": float(np.mean([r["violation"] for r in normal])),
            "viol_anomalous_mean": float(np.mean([r["violation"] for r in anom]))
            if anom else 0.0,
            "viol_first_half": float(np.mean(halves[0])),
            "viol_second_half": float(np.mean(halves[1])),
            "failures_total": int(sum(r["n_failures"] for r in runs)),
        }
    return out


def render_ascii(job: str, n_adaptive: int = 55, seed: int = 0) -> str:
    res = get_or_run(job, "enel", n_adaptive=n_adaptive, seed=seed)
    target = res["target"]
    lines = [f"{job}: runtime vs target={target:.0f}s "
             f"(# anomalous, . normal; bar = overshoot)"]
    for r in res["runs"]:
        over = max(0.0, r["runtime"] - target)
        bar = "#" if r["anomalous"] else "."
        lines.append(f"run {r['run_idx']:3d} {bar} "
                     f"{r['runtime']:7.0f}s |{'=' * min(60, int(over / 5))}")
    return "\n".join(lines)


def main(n_adaptive: int = 55):
    for job in ("lr", "mpc", "kmeans", "gbt"):
        s = summarize(job, n_adaptive)
        for method, v in s.items():
            print(f"fig4,{job},{method},viol_1st_half={v['viol_first_half']:.1f}s,"
                  f"viol_2nd_half={v['viol_second_half']:.1f}s,"
                  f"viol_anomalous={v['viol_anomalous_mean']:.1f}s")
    return True


if __name__ == "__main__":
    main()
