"""Fill EXPERIMENTS.md markers from artifacts (dry-run JSONs + campaign
results + hillclimb iterations).

    PYTHONPATH=src:. python -m benchmarks.make_report
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parent.parent
DRYRUN = ROOT / "artifacts" / "dryrun"
EXPS = ROOT / "artifacts" / "experiments"


def _load(pattern: str):
    return [json.loads(p.read_text()) for p in sorted(DRYRUN.glob(pattern))]


def dryrun_summary() -> str:
    lines = ["| arch | shape | mesh | status | flops/dev | bytes/dev | "
             "coll bytes/dev | args (GB/dev) | temp (GB/dev) |",
             "|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("pod1", "pod2"):
        for r in _load(f"*--{mesh}.json"):
            if r.get("status") == "skipped":
                lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                             f"skip (long-ctx full-attn) | | | | | |")
                continue
            ma = r.get("memory_analysis", {})
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | {r['status']} | "
                f"{r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} | "
                f"{r['collective_bytes_per_device']:.2e} | "
                f"{ma.get('argument_size_in_bytes', 0)/1e9:.2f} | "
                f"{ma.get('temp_size_in_bytes', 0)/1e9:.2f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    from benchmarks.roofline import render
    return render("pod1")


def roofline_notes() -> str:
    rows = [r for r in _load("*--pod1.json") if r.get("status") == "ok"]
    notes = []
    for r in rows:
        t = r["roofline"]
        dom = r["dominant"]
        if dom == "t_memory":
            fix = ("keep activations bf16 / fuse norm chains; raise arithmetic "
                   "intensity (larger per-chip batch)")
            if r["shape"].startswith("decode") or r["shape"] == "long_500k":
                fix = "batch more sequences per chip; quantize the KV cache"
            if r["arch"] == "xlstm-350m" and r["shape"] != "decode_32k":
                fix = ("sLSTM is sequential: fuse the whole recurrence into "
                       "one kernel so R stays in VMEM (mlstm_chunk-style)")
        elif dom == "t_collective":
            fix = ("fewer FSDP re-gathers (lower grad_accum), int8 grad "
                   "all-reduce, keep experts EP-resident")
        else:
            fix = "already compute-bound: tune kernel block shapes"
        notes.append(f"* `{r['arch']} x {r['shape']}`: dominant {dom[2:]} "
                     f"({max(t.values()):.3f}s); useful-FLOPs "
                     f"{r['useful_flops_ratio']:.2f}; next lever: {fix}")
    return "\n".join(notes)


def table3() -> str:
    from benchmarks.table3_prediction import render, run
    jobs = []
    for j in ("lr", "mpc", "kmeans", "gbt"):
        if (EXPS / f"{j}--enel--55.json").exists():
            jobs.append(j)
    if not jobs:
        return "(campaign artifacts missing — run benchmarks.table3_prediction)"
    return render(run(jobs=jobs, n_adaptive=55))


def repro_claims() -> str:
    out = []
    for j in ("lr", "mpc", "kmeans", "gbt"):
        pe = EXPS / f"{j}--enel--55.json"
        pl = EXPS / f"{j}--ellis--55.json"
        if not (pe.exists() and pl.exists()):
            continue
        re_ = json.loads(pe.read_text())
        rl = json.loads(pl.read_text())
        ve = [r["violation"] / 60 for r in re_["runs"]]
        vl = [r["violation"] / 60 for r in rl["runs"]]
        anom_e = [r["violation"] / 60 for r in re_["runs"] if r["anomalous"]]
        anom_l = [r["violation"] / 60 for r in rl["runs"] if r["anomalous"]]
        h1, h2 = np.array_split(np.array(ve), 2)
        out.append(
            f"* **{j}**: Enel CVS mean {np.mean(ve):.2f} m vs Ellis "
            f"{np.mean(vl):.2f} m; Enel improves over time "
            f"(1st half {h1.mean():.2f} -> 2nd half {h2.mean():.2f} m); "
            f"anomalous-phase CVS: Enel {np.mean(anom_e):.2f} m vs Ellis "
            f"{np.mean(anom_l):.2f} m "
            f"({'more robust' if np.mean(anom_e) <= np.mean(anom_l) else 'less robust'} under failures)")
    return "\n".join(out) if out else "(pending campaign)"


def fig5() -> str:
    try:
        from benchmarks.fig5_timing import measure
        # lr (few stages/component) vs gbt (most components+stages): the
        # extremes the paper's Fig. 5 contrasts
        rows = [measure(j, repeats=5) for j in ("lr", "gbt")]
        lines = ["| job | graphs/run | fine-tune (s) | predict (s) |",
                 "|---|---|---|---|"]
        for r in rows:
            lines.append(f"| {r['job']} | {r['n_graphs']} | "
                         f"{r['fit_s_median']:.2f} "
                         f"(IQR {r['fit_s_iqr']:.2f}) | "
                         f"{r['predict_s_median']:.3f} |")
        return "\n".join(lines)
    except Exception as e:
        return f"(fig5 failed: {e})"


def fleet_bench_table() -> str:
    """Render the ``fleet`` + ``fused`` row families of BENCH_decision.json.

    Schema-tolerant by construction: older JSONs predate the ``fused``
    section and the 128/1024-size rows, and fused rows themselves predate
    some columns — every field goes through ``.get`` and missing cells
    render as an em-dash instead of raising KeyError (the read-side mirror
    of the merge-don't-clobber convention in ``merge_bench_json``)."""
    p = ROOT / "BENCH_decision.json"
    if not p.exists():
        return "(BENCH_decision.json missing — run benchmarks.fleet_bench)"
    data = json.loads(p.read_text())

    def fmt(row, key, nd=1, suffix=""):
        v = row.get(key)
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return "—"
        return f"{v:.{nd}f}{suffix}"

    fleet = {r.get("fleet_size"): r for r in data.get("fleet", [])
             if r.get("fleet_size") is not None}
    fused = {r.get("fleet_size"): r for r in data.get("fused", [])
             if r.get("fleet_size") is not None}
    if not fleet and not fused:
        return "(no fleet/fused rows yet — run benchmarks.fleet_bench)"
    lines = ["| fleet | batched dec/s | vs sequential | fused dec/s | "
             "fused steps/s | fused vs live stepped | fused vs jit twin |",
             "|---|---|---|---|---|---|---|"]
    for size in sorted(set(fleet) | set(fused)):
        fl, fu = fleet.get(size, {}), fused.get(size, {})
        est = " (est)" if fu.get("live_estimated") else ""
        lines.append(
            f"| {size} | {fmt(fl, 'batched_dec_per_s')} | "
            f"{fmt(fl, 'speedup', nd=2, suffix='x')} | "
            f"{fmt(fu, 'fused_dec_per_s')} | "
            f"{fmt(fu, 'fused_steps_per_s')} | "
            f"{fmt(fu, 'speedup_vs_live', suffix='x')}{est} | "
            f"{fmt(fu, 'speedup_vs_stepped', nd=2, suffix='x')} |")
    for r in data.get("fused_race", []):
        lines.append(
            f"\nScenario race (fleet {r.get('fleet_size', '?')}, "
            f"{r.get('scenario', '?')}): fused "
            f"{fmt(r, 'fused_s_median', nd=3)}s vs stepped "
            f"{fmt(r, 'stepped_s_median', nd=3)}s — "
            f"{fmt(r, 'speedup_fused', suffix='x')} "
            f"(plan build {fmt(r, 'plan_build_s', nd=2)}s, host-side, "
            "once per campaign).")
    return "\n".join(lines)


def controller_health_table() -> str:
    """One controller-health table over every registry-snapshot producer:
    the chaos fleet rows' ``controller_health`` registry dumps, the
    ``latency`` histogram section (fleet_bench + chaos_suite sources), the
    ``fleet_budget`` fault-envelope counters, and the ``obs_overhead``
    telemetry-cost row.  Schema-tolerant: every field through ``.get`` so
    JSONs predating the observability PR render with em-dashes."""
    p = ROOT / "BENCH_decision.json"
    if not p.exists():
        return ("(BENCH_decision.json missing — run benchmarks.fleet_bench "
                "/ benchmarks.chaos_suite)")
    data = json.loads(p.read_text())

    def num(v, nd=0, scale=1.0, suffix=""):
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return "—"
        return f"{v * scale:.{nd}f}{suffix}"

    lines = []
    lat = [r for r in data.get("latency", []) if r.get("count")]
    if lat:
        lines.append("| source | metric | labels | n | p50 (ms) | "
                     "p95 (ms) | p99 (ms) | max (ms) |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for r in lat:
            lab = ",".join(f"{k}={v}" for k, v in
                           sorted((r.get("labels") or {}).items())) or "—"
            lines.append(
                f"| {r.get('source', '—')} | {r.get('metric', '—')} | "
                f"{lab} | {num(r.get('count'))} | "
                f"{num(r.get('p50'), 3, 1e3)} | {num(r.get('p95'), 3, 1e3)} | "
                f"{num(r.get('p99'), 3, 1e3)} | {num(r.get('max'), 3, 1e3)} |")
    counters = []
    budget = data.get("fleet_budget", {})
    for k in ("fallback_decisions", "guardrail_trips", "retries",
              "dispatch_failures", "breaker_trips", "shed_requests"):
        if k in budget:
            counters.append(("fleet_budget (clean campaign)", k, budget[k]))
    for row in data.get("chaos", []):
        for h in row.get("controller_health") or []:
            if h.get("kind") == "counter" and h.get("value"):
                lab = ",".join(f"{k}={v}" for k, v in
                               sorted((h.get("labels") or {}).items()))
                counters.append((f"chaos:{row.get('scenario', '?')}",
                                 f"{h.get('metric')}{{{lab}}}",
                                 h.get("value")))
    if counters:
        lines.append("")
        lines.append("| source | counter | value |")
        lines.append("|---|---|---|")
        for src, name, val in counters:
            lines.append(f"| {src} | {name} | {num(val)} |")
    ov = data.get("obs_overhead", {})
    if ov:
        lines.append(
            f"\nIn-scan telemetry overhead (fused fleet "
            f"{ov.get('fleet_size', '?')}): telemetry off "
            f"{num(ov.get('off_s_median'), 0, 1e3, 'ms')} vs on "
            f"{num(ov.get('on_s_median'), 0, 1e3, 'ms')} — "
            f"{num(ov.get('overhead'), 1, 1e2, '%')} "
            f"(ENEL_OBS=0 compiles the off variant).")
    return "\n".join(lines) if lines else \
        "(no controller-health rows yet — run benchmarks.fleet_bench / " \
        "benchmarks.chaos_suite)"


def perf_log() -> str:
    cells = {
        "olmoe-1b-7b--train_4k": ["-base", "-opt1", "-opt2", "-opt3"],
        "arctic-480b--train_4k": ["-base", "-opt1", "-opt2", "-opt3"],
        "xlstm-350m--train_4k": ["-base", "-opt1", "-opt2"],
    }
    lines = []
    for cell, tags in cells.items():
        lines.append(f"\n### {cell}\n")
        lines.append("| variant | overrides | t_comp | t_mem | t_coll | "
                     "dominant | useful | temp GB/dev |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for tag in tags:
            p = DRYRUN / f"{cell}--pod1{tag}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r.get("status") != "ok":
                lines.append(f"| {tag[1:]} | — | ERROR | | | | | |")
                continue
            t = r["roofline"]
            ov = ",".join(f"{k}={v}" for k, v in
                          (r.get("overrides") or {}).items()) or "(none)"
            lines.append(
                f"| {tag[1:]} | {ov} | {t['t_compute']:.3f} | "
                f"{t['t_memory']:.3f} | {t['t_collective']:.3f} | "
                f"{r['dominant'][2:]} | {r['useful_flops_ratio']:.3f} | "
                f"{r['memory_analysis'].get('temp_size_in_bytes', 0)/1e9:.1f} |")
    return "\n".join(lines)


MARKERS = {
    "<!-- TABLE3 -->": table3,
    "<!-- REPRO-CLAIMS -->": repro_claims,
    "<!-- FIG5 -->": fig5,
    "<!-- DRYRUN-SUMMARY -->": dryrun_summary,
    "<!-- ROOFLINE-TABLE -->": roofline_table,
    "<!-- ROOFLINE-NOTES -->": roofline_notes,
    "<!-- PERF-LOG -->": perf_log,
    "<!-- FLEET-BENCH -->": fleet_bench_table,
    "<!-- CONTROLLER-HEALTH -->": controller_health_table,
}


_SECTION_TITLES = {
    "<!-- TABLE3 -->": "Table 3: prediction accuracy",
    "<!-- REPRO-CLAIMS -->": "Reproduction claims",
    "<!-- FIG5 -->": "Fig. 5: fit/predict timing",
    "<!-- DRYRUN-SUMMARY -->": "Dry-run summary",
    "<!-- ROOFLINE-TABLE -->": "Roofline",
    "<!-- ROOFLINE-NOTES -->": "Roofline notes",
    "<!-- PERF-LOG -->": "Perf log",
    "<!-- FLEET-BENCH -->": "Fleet / fused campaign bench",
    "<!-- CONTROLLER-HEALTH -->": "Controller health (observability)",
}


def _fallback_template() -> str:
    """Minimal template when EXPERIMENTS.template.md is absent: one
    section per registered marker, so the report is still generable."""
    parts = ["# Experiments\n"]
    for marker in MARKERS:
        parts.append(f"\n## {_SECTION_TITLES.get(marker, marker)}\n")
        parts.append(f"\n{marker}\n")
    return "".join(parts)


def main():
    path = ROOT / "EXPERIMENTS.md"
    template = ROOT / "benchmarks" / "EXPERIMENTS.template.md"
    text = template.read_text() if template.exists() \
        else _fallback_template()   # always regenerate from the template
    for marker, fn in MARKERS.items():
        if marker in text:
            try:
                content = fn()
            except Exception as e:
                content = f"(generation failed: {type(e).__name__}: {e})"
            text = text.replace(marker, content)
            print(f"[report] filled {marker}")
    path.write_text(text)
    print("[report] EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
