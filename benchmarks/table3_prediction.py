"""Table III analogue: evolution of CVC/CVS over adaptive-run windows,
Enel vs Ellis, per job."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.experiment import campaign_window_stats, get_or_run

JOBS_ORDER = ["lr", "mpc", "kmeans", "gbt"]


def run(jobs: List[str] = JOBS_ORDER, methods=("enel", "ellis"),
        n_adaptive: int = 55, seed: int = 0) -> Dict:
    table = {}
    for job in jobs:
        for method in methods:
            res = get_or_run(job, method, n_adaptive=n_adaptive, seed=seed)
            table[(job, method)] = campaign_window_stats(res)
    return table


def render(table: Dict) -> str:
    lines = ["| job | method | " + " | ".join(
        f"W{i+1} cvc x̄/x̃ · cvs x̄/x̃ (m)" for i in range(5)) + " |",
        "|---|---|" + "---|" * 5]
    for (job, method), ws in sorted(table.items()):
        cells = [f"{w['cvc_mean']:.2f}/{w['cvc_median']:.2f} · "
                 f"{w['cvs_mean']:.2f}/{w['cvs_median']:.2f}" for w in ws]
        lines.append(f"| {job} | {method} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def main(n_adaptive: int = 55):
    table = run(n_adaptive=n_adaptive)
    print(render(table))
    return table


if __name__ == "__main__":
    main()
