"""Fleet-scale decision benchmark: cross-job batched dispatch vs sequential
per-job ``recommend``, plus the campaign compile-count budget.

Two measurements:

* **Throughput** — a fleet of concurrent jobs (all four job classes x seeds,
  cycling) each needs a mid-run rescaling decision.  ``sequential`` answers
  them one ``EnelScaler.recommend`` at a time (the dense per-job engine);
  ``batched`` prepares shape-bucketed requests and answers all of them in
  one ``DecisionService.decide`` call (sparse engine, one jit dispatch per
  bucket, one transfer per group).  Reported at fleet sizes 1/8/32.

* **Compile budget** — a full 4-job mini-campaign (profiling + adaptive runs
  covering every remaining-component count) must compile the fleet sweep at
  most once per visited shape-bucket key: the bucket ladders exist precisely
  so this stays a small constant (~12) instead of O(runs x components).
  The script FAILS (exit 1) if the trace count exceeds the visited-bucket
  bound, or if the ladder lets the campaign visit more than MAX_BUCKETS
  distinct keys.

Rows are merged into ``BENCH_decision.json`` (``fleet`` + ``fleet_budget``)
next to the fig5/fit/decision rows; CI uploads the JSON as an artifact.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List

import numpy as np

try:
    from benchmarks.fig5_timing import med_iqr, merge_bench_json
except ImportError:                      # run as a script from benchmarks/
    from fig5_timing import med_iqr, merge_bench_json
from repro.core import model as enel_model
from repro.core.graph import summary_node
from repro.core.service import DecisionService
from repro.dataflow import FleetCampaign, JobExperiment
from repro.dataflow.runner import _component_nodes, _future_nodes, _to_graph
from repro.sim.engine import SimStepRequest

JOB_CYCLE = ("lr", "mpc", "kmeans", "gbt")
MAX_BUCKETS = 12          # bucket-ladder bound for the 4-job mini-campaign


def _decision_context(exp: JobExperiment) -> Dict:
    """The runner's mid-run decision kwargs (component 0 finished — the
    largest sweep of the job), mirroring fig5's measure_decision."""
    job = exp.job
    builder = lambda ci, a, z, pr: _to_graph(
        _future_nodes(exp.encoder, job, ci, a, z), pr, ci)
    comp = exp.sim.run_component(job, 0, clock=0.0, start_scaleout=8,
                                 end_scaleout=8, inject_failures=False,
                                 failures_log=[])
    summ = summary_node(_component_nodes(exp.encoder, job, comp), name="P0")
    return dict(graph_builder=builder, next_comp=1,
                n_components=job.n_components, elapsed=comp.runtime,
                current_scaleout=8, target_runtime=exp.target,
                current_summary=summ)


def build_base_experiments(profile_runs: int = 3) -> List[JobExperiment]:
    exps = []
    for i, key in enumerate(JOB_CYCLE):
        exp = JobExperiment(key, seed=i)
        exp.profile(profile_runs)
        exps.append(exp)
    return exps


def measure_fleet(base_exps: List[JobExperiment], sizes=(1, 8, 32),
                  repeats: int = 7) -> List[Dict]:
    """decisions/sec: sequential per-job recommend vs batched service."""
    service = DecisionService()
    contexts = [(exp, _decision_context(exp)) for exp in base_exps]
    rows = []
    for size in sizes:
        fleet = [contexts[i % len(contexts)] for i in range(size)]
        for _ in range(2):           # untimed rounds: jit warmup + settling
            for exp, kw in fleet[:len(contexts)]:
                exp.enel.recommend(**kw)
            service.decide(
                [exp.enel.prepare_request(**kw) for exp, kw in fleet])
        seq_t, bat_t = [], []
        for _ in range(repeats):
            t0 = time.time()
            for exp, kw in fleet:
                exp.enel.recommend(**kw)
            seq_t.append(time.time() - t0)
            t0 = time.time()
            service.decide(
                [exp.enel.prepare_request(**kw) for exp, kw in fleet])
            bat_t.append(time.time() - t0)
        seq_m, bat_m = med_iqr(seq_t), med_iqr(bat_t)
        seq, bat = seq_m["median"], bat_m["median"]
        rows.append({
            "fleet_size": size,
            "sequential_dec_per_s": size / seq,
            "batched_dec_per_s": size / bat,
            "speedup": seq / bat,
            "sequential_ms_per_decision": seq / size * 1e3,
            "sequential_ms_iqr": seq_m["iqr"] / size * 1e3,
            "batched_ms_per_decision": bat / size * 1e3,
            "batched_ms_iqr": bat_m["iqr"] / size * 1e3,
        })
    return rows


def measure_budget(adaptive_runs: int = 2,
                   profile_runs: int = 3) -> Dict:
    """Compile-count budget: a fresh 4-job mini-campaign through the fleet
    service must compile at most once per visited shape-bucket key."""
    enel_model.reset_trace_counts()
    exps = [JobExperiment(key, seed=10 + i)
            for i, key in enumerate(JOB_CYCLE)]
    campaign = FleetCampaign(exps)
    campaign.profile(profile_runs)
    visited = set()
    for exp in exps:                      # individually: J=1 dispatches
        for _ in range(adaptive_runs):
            gen = exp.adaptive_run_gen("enel", False)
            try:
                req = next(gen)
                while True:
                    if isinstance(req, SimStepRequest):
                        req = gen.send(exp.backend.step([req])[0])
                    else:
                        visited.add(req.bucket_key)
                        req = gen.send(exp.service.decide([req])[0])
            except StopIteration:
                pass
    compiles = enel_model.trace_count("fleet_sweep")
    svc = campaign.service
    return {"adaptive_runs_per_job": adaptive_runs,
            "visited_buckets": len(visited),
            "fleet_sweep_compiles": compiles,
            "bucket_bound": MAX_BUCKETS,
            "decisions": sum(st.decide_calls for e in exps
                             for st in e.stats if st.kind == "enel"),
            # fault-envelope health: a clean campaign must answer every
            # decision from the model (all of these stay 0)
            "fallback_decisions": svc.fallback_decisions,
            "guardrail_trips": svc.guardrail_trips,
            "retries": svc.retries,
            "dispatch_failures": svc.dispatch_failures,
            "breaker_trips": svc.breaker_trips,
            "shed_requests": svc.shed_requests}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="1,8,32")
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--profile-runs", type=int, default=3)
    ap.add_argument("--adaptive-runs", type=int, default=2)
    ap.add_argument("--out", default="BENCH_decision.json")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))

    # budget FIRST: it must observe a cold jit cache — running the fleet
    # throughput sweep beforehand would prewarm bucket compiles and hide
    # regressions from the trace counter
    budget = measure_budget(args.adaptive_runs, args.profile_runs)
    print(f"budget,buckets={budget['visited_buckets']},"
          f"compiles={budget['fleet_sweep_compiles']},"
          f"decisions={budget['decisions']},bound={budget['bucket_bound']}")

    base = build_base_experiments(args.profile_runs)
    fleet_rows = measure_fleet(base, sizes, args.repeats)
    for r in fleet_rows:
        print(f"fleet,size={r['fleet_size']},"
              f"seq={r['sequential_dec_per_s']:.1f}/s,"
              f"batched={r['batched_dec_per_s']:.1f}/s,"
              f"speedup={r['speedup']:.2f}x")

    merge_bench_json(args.out, {"fleet": fleet_rows, "fleet_budget": budget})
    print(f"wrote {os.path.abspath(args.out)}")

    ok = True
    if budget["fleet_sweep_compiles"] > budget["visited_buckets"]:
        print(f"FAIL: {budget['fleet_sweep_compiles']} compiles > "
              f"{budget['visited_buckets']} visited buckets "
              "(recompilation within a bucket)")
        ok = False
    if budget["visited_buckets"] > MAX_BUCKETS:
        print(f"FAIL: campaign visited {budget['visited_buckets']} buckets "
              f"> ladder bound {MAX_BUCKETS}")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
