"""Fleet-scale decision benchmark: cross-job batched dispatch vs sequential
per-job ``recommend``, plus the campaign compile-count budget.

Three measurements:

* **Throughput** — a fleet of concurrent jobs (all four job classes x seeds,
  cycling) each needs a mid-run rescaling decision.  ``sequential`` answers
  them one ``EnelScaler.recommend`` at a time (the dense per-job engine);
  ``batched`` prepares shape-bucketed requests and answers all of them in
  one ``DecisionService.decide`` call (sparse engine, one jit dispatch per
  bucket, one transfer per group).  Reported at fleet sizes 1/8/32.

* **Compile budget** — a full 4-job mini-campaign (profiling + adaptive runs
  covering every remaining-component count) must compile the fleet sweep at
  most once per visited shape-bucket key: the bucket ladders exist precisely
  so this stays a small constant (~12) instead of O(runs x components).
  The script FAILS (exit 1) if the trace count exceeds the visited-bucket
  bound, or if the ladder lets the campaign visit more than MAX_BUCKETS
  distinct keys.

* **Fused campaign** — the whole-campaign-on-device race
  (``core/campaign_kernel.py``) against two stepped baselines: the python
  loop over the *same* jitted step body (bit-exact twin, isolating
  per-step dispatch overhead) and the LIVE production path
  (``adaptive_campaign``: host graph building, service dispatch,
  sequential per-job fits — the work fusion actually eliminates).  A numpy
  event-loop replay of the fused schedule (sim only — no decisions/fit,
  so fused speedups over it are lower bounds) anchors the absolute scale.
  The live and numpy baselines cap at ``--numpy-max`` slots and larger
  fleets extrapolate linearly (both paths are sequential per job), marked
  ``*_estimated``.  Default sizes 32/128/1024 measure the ROADMAP
  "fleet sizes in the thousands" claim instead of asserting it.

Rows are merged into ``BENCH_decision.json`` (``fleet`` + ``fleet_budget``
+ ``fused``) next to the fig5/fit/decision rows; CI uploads the JSON as an
artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

try:
    from benchmarks.fig5_timing import (med_iqr, merge_bench_json,
                                        merge_latency_rows)
except ImportError:                      # run as a script from benchmarks/
    from fig5_timing import med_iqr, merge_bench_json, merge_latency_rows
from repro import obs
from repro.core import model as enel_model
from repro.core.graph import summary_node
from repro.core.service import DecisionService
from repro.dataflow import FleetCampaign, JobExperiment
from repro.dataflow.runner import _component_nodes, _future_nodes, _to_graph
from repro.sim.engine import SimStepRequest

JOB_CYCLE = ("lr", "mpc", "kmeans", "gbt")
MAX_BUCKETS = 12          # bucket-ladder bound for the 4-job mini-campaign


def _decision_context(exp: JobExperiment) -> Dict:
    """The runner's mid-run decision kwargs (component 0 finished — the
    largest sweep of the job), mirroring fig5's measure_decision."""
    job = exp.job
    builder = lambda ci, a, z, pr: _to_graph(
        _future_nodes(exp.encoder, job, ci, a, z), pr, ci)
    comp = exp.sim.run_component(job, 0, clock=0.0, start_scaleout=8,
                                 end_scaleout=8, inject_failures=False,
                                 failures_log=[])
    summ = summary_node(_component_nodes(exp.encoder, job, comp), name="P0")
    return dict(graph_builder=builder, next_comp=1,
                n_components=job.n_components, elapsed=comp.runtime,
                current_scaleout=8, target_runtime=exp.target,
                current_summary=summ)


def build_base_experiments(profile_runs: int = 3) -> List[JobExperiment]:
    exps = []
    for i, key in enumerate(JOB_CYCLE):
        exp = JobExperiment(key, seed=i)
        exp.profile(profile_runs)
        exps.append(exp)
    return exps


def measure_fleet(base_exps: List[JobExperiment], sizes=(1, 8, 32),
                  repeats: int = 7) -> List[Dict]:
    """decisions/sec: sequential per-job recommend vs batched service."""
    service = DecisionService()
    contexts = [(exp, _decision_context(exp)) for exp in base_exps]
    rows = []
    for size in sizes:
        fleet = [contexts[i % len(contexts)] for i in range(size)]
        for _ in range(2):           # untimed rounds: jit warmup + settling
            for exp, kw in fleet[:len(contexts)]:
                exp.enel.recommend(**kw)
            service.decide(
                [exp.enel.prepare_request(**kw) for exp, kw in fleet])
        seq_t, bat_t = [], []
        for _ in range(repeats):
            t0 = time.time()
            for exp, kw in fleet:
                exp.enel.recommend(**kw)
            seq_t.append(time.time() - t0)
            t0 = time.time()
            service.decide(
                [exp.enel.prepare_request(**kw) for exp, kw in fleet])
            bat_t.append(time.time() - t0)
        seq_m, bat_m = med_iqr(seq_t), med_iqr(bat_t)
        seq, bat = seq_m["median"], bat_m["median"]
        rows.append({
            "fleet_size": size,
            "sequential_dec_per_s": size / seq,
            "batched_dec_per_s": size / bat,
            "speedup": seq / bat,
            "sequential_ms_per_decision": seq / size * 1e3,
            "sequential_ms_iqr": seq_m["iqr"] / size * 1e3,
            "batched_ms_per_decision": bat / size * 1e3,
            "batched_ms_iqr": bat_m["iqr"] / size * 1e3,
        })
    return rows


def _fused_fleet(size: int, profile_runs: int,
                 seed0: int = 20) -> FleetCampaign:
    """A fleet of `size` slots cycling the four job classes with ONE seed
    per class, so the fused plan dedups to 4 structural/history classes no
    matter the fleet size (plan build stays O(classes), not O(fleet))."""
    exps = [JobExperiment(JOB_CYCLE[i % len(JOB_CYCLE)],
                          seed=seed0 + i % len(JOB_CYCLE))
            for i in range(size)]
    camp = FleetCampaign(exps, DecisionService(), engine="batched")
    camp.profile(profile_runs)
    return camp


def _numpy_replay_times(exps, ys, n_runs: int, c_max: int,
                        repeats: int) -> List[float]:
    """Wall time of the numpy per-job event loop replaying the fused
    z-schedule (sim only — the numpy path has no batched decision or
    resident-fit equivalent, so this is the sim floor, not the campaign)."""
    from repro.sim.engine import NumpySimBackend
    from repro.sim.scenarios import make_scenario
    a = np.asarray(ys["a"]).astype(int)
    z = np.asarray(ys["z"]).astype(int)
    npb = NumpySimBackend()
    for j, e in enumerate(exps):
        npb.register(e.job, seed=e.seed, scenario=make_scenario("baseline"))
    times = []
    for _ in range(repeats):
        t0 = time.time()
        for r in range(n_runs):
            base = r * c_max
            for j, e in enumerate(exps):
                npb.begin_run(j)
                clock = 0.0
                for k in range(e.job.n_components):
                    res = npb.step([SimStepRequest(
                        j, k, int(a[base + k, j]), int(z[base + k, j]),
                        clock, True)])[0]
                    clock = res.clock_end
        times.append(time.time() - t0)
    return times


def measure_fused(sizes=(32, 128, 1024), n_runs: int = 2, repeats: int = 5,
                  profile_runs: int = 3, numpy_max: int = 32,
                  live_max: int = 32, big_repeats: int = 2) -> List[Dict]:
    """Whole-campaign wall time at each fleet size, median-of-k + IQR,
    across three drivers of the SAME protocol work (n_runs adaptive runs,
    identical decision cadence, one scratch + one tune fit window):

    * ``fused`` — ONE scanned jit (core/campaign_kernel.py);
    * ``stepped`` — python loop over the same jitted step body (bit-exact
      twin; isolates per-step dispatch overhead);
    * ``live`` — the production stepped path, ``adaptive_campaign`` on a
      fresh twin fleet per repeat: host python graph building, service
      dispatch, per-job sequential ``fit_resident`` (what fused replaces).

    ``live`` is sequential per job (linear in fleet size), so sizes above
    ``live_max`` extrapolate linearly from the last measured size and are
    marked ``live_estimated`` — same convention as the numpy sim floor.
    Sizes above ``live_max`` also drop to ``big_repeats`` timed repeats
    (single-core CPU: a 1024-slot campaign is minutes per repeat).

    Also verifies, per size, that the timed repeats add ZERO new traces
    (the compile count is bounded by the bucket ladder, not by repeats)
    and that every decision left the scan finite."""
    import jax

    from repro.core import campaign_kernel as ck

    rows: List[Dict] = []
    numpy_per_step = None      # s per (component-step x job), from replay
    live_per_step = None
    for size in sizes:
        reps = repeats if size <= live_max else max(big_repeats, 2)
        camp = _fused_fleet(size, profile_runs)
        t0 = time.time()
        plan = ck.build_plan(camp.experiments, n_runs)
        plan_build_s = time.time() - t0
        trace0 = enel_model.trace_count("fused_campaign")
        c_f, ys_f = ck.run_fused(plan)         # warmup: compiles the scan
        jax.block_until_ready(ys_f)
        _, ys_s = ck.run_stepped(plan)         # warmup: compiles the step
        jax.block_until_ready(ys_s)
        warm = enel_model.trace_count("fused_campaign") - trace0

        fused_t, stepped_t = [], []
        for _ in range(reps):
            t0 = time.time()
            jax.block_until_ready(ck.run_fused(plan)[1])
            fused_t.append(time.time() - t0)
            t0 = time.time()
            jax.block_until_ready(ck.run_stepped(plan)[1])
            stepped_t.append(time.time() - t0)
        new_traces = (enel_model.trace_count("fused_campaign")
                      - trace0 - warm)

        fleet_steps = int(np.asarray(plan.host["n_comp"]).sum()) * n_runs
        decisions = int(np.asarray(ys_f["decided"]).sum())
        nonfinite = int(np.asarray(c_f["nonfinite"]).sum())
        if size <= numpy_max:
            m = med_iqr(_numpy_replay_times(camp.experiments, ys_f, n_runs,
                                            plan.static.c_max, reps))
            numpy_s, numpy_iqr, n_est = m["median"], m["iqr"], False
            numpy_per_step = numpy_s / fleet_steps
        else:                  # linear in fleet-steps from the last replay
            numpy_s = (numpy_per_step or 0.0) * fleet_steps
            numpy_iqr, n_est = 0.0, True

        if size <= live_max:
            _fused_fleet(size, profile_runs).adaptive_campaign(n_runs)
            live_t = []                       # ^ untimed live-bucket warmup
            for _ in range(min(reps, 3)):     # fresh twin per repeat so the
                twin = _fused_fleet(size, profile_runs)   # scratch cadence
                t0 = time.time()              # matches the fused plan
                twin.adaptive_campaign(n_runs)
                live_t.append(time.time() - t0)
            m = med_iqr(live_t)
            live_s, live_iqr, l_est = m["median"], m["iqr"], False
            live_per_step = live_s / fleet_steps
        else:                  # the live path is sequential per job
            live_s = (live_per_step or 0.0) * fleet_steps
            live_iqr, l_est = 0.0, True

        fm, sm = med_iqr(fused_t), med_iqr(stepped_t)
        rows.append({
            "fleet_size": size, "runs_per_campaign": n_runs,
            "repeats": reps, "steps": plan.n_steps,
            "fleet_steps": fleet_steps, "decisions": decisions,
            "plan_build_s": plan_build_s,
            "fused_s_median": fm["median"], "fused_s_iqr": fm["iqr"],
            "stepped_s_median": sm["median"], "stepped_s_iqr": sm["iqr"],
            "live_s_median": live_s, "live_s_iqr": live_iqr,
            "live_estimated": l_est,
            "fused_steps_per_s": fleet_steps / fm["median"],
            "fused_dec_per_s": decisions / fm["median"],
            "stepped_steps_per_s": fleet_steps / sm["median"],
            "stepped_dec_per_s": decisions / sm["median"],
            "live_dec_per_s": (decisions / live_s) if live_s else 0.0,
            "speedup_vs_stepped": sm["median"] / fm["median"],
            "speedup_vs_live": (live_s / fm["median"]) if live_s else 0.0,
            "numpy_s_median": numpy_s, "numpy_s_iqr": numpy_iqr,
            "numpy_estimated": n_est, "numpy_sim_only": True,
            "numpy_steps_per_s": (fleet_steps / numpy_s) if numpy_s else 0.0,
            "speedup_vs_numpy": (numpy_s / fm["median"]) if numpy_s else 0.0,
            "new_traces_during_timing": new_traces,
            "nonfinite_decisions": nonfinite,
        })
    return rows


def measure_budget(adaptive_runs: int = 2,
                   profile_runs: int = 3) -> Dict:
    """Compile-count budget: a fresh 4-job mini-campaign through the fleet
    service must compile at most once per visited shape-bucket key."""
    enel_model.reset_trace_counts()
    exps = [JobExperiment(key, seed=10 + i)
            for i, key in enumerate(JOB_CYCLE)]
    campaign = FleetCampaign(exps)
    campaign.profile(profile_runs)
    visited = set()
    for exp in exps:                      # individually: J=1 dispatches
        for _ in range(adaptive_runs):
            gen = exp.adaptive_run_gen("enel", False)
            try:
                req = next(gen)
                while True:
                    if isinstance(req, SimStepRequest):
                        req = gen.send(exp.backend.step([req])[0])
                    else:
                        visited.add(req.bucket_key)
                        req = gen.send(exp.service.decide([req])[0])
            except StopIteration:
                pass
    compiles = enel_model.trace_count("fleet_sweep")
    # fault-envelope health straight from the registry-backed service
    # stats: a clean campaign must answer every decision from the model
    # (every robustness counter stays 0)
    health = {k: v for k, v in campaign.service.stats().items()
              if k not in ("decisions", "dispatches", "batched_away",
                           "breaker_state")}
    return {"adaptive_runs_per_job": adaptive_runs,
            "visited_buckets": len(visited),
            "fleet_sweep_compiles": compiles,
            "bucket_bound": MAX_BUCKETS,
            "decisions": sum(st.decide_calls for e in exps
                             for st in e.stats if st.kind == "enel"),
            **health}


def measure_obs_overhead(size: int = 8, n_runs: int = 2, repeats: int = 5,
                         profile_runs: int = 3) -> Dict:
    """In-scan telemetry cost: the SAME fused campaign compiled with the
    telemetry carry block on vs off (``build_plan(..., telemetry=)``),
    per-decision wall-time delta.  This is the zero-cost-when-disabled
    contract made measurable: ``ENEL_OBS=0`` compiles the ``off`` jaxpr."""
    import jax

    from repro.core import campaign_kernel as ck

    camp = _fused_fleet(size, profile_runs, seed0=40)
    out: Dict = {"fleet_size": size, "runs_per_campaign": n_runs,
                 "repeats": repeats}
    for tel in (False, True):
        plan = ck.build_plan(camp.experiments, n_runs, telemetry=tel)
        _, ys = ck.run_fused(plan)          # warmup: compiles this variant
        jax.block_until_ready(ys)
        decisions = int(np.asarray(ys["decided"]).sum())
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(ck.run_fused(plan)[1])
            ts.append(time.time() - t0)
        m = med_iqr(ts)
        key = "on" if tel else "off"
        out[f"{key}_s_median"] = m["median"]
        out[f"{key}_s_iqr"] = m["iqr"]
        out[f"{key}_ms_per_decision"] = \
            m["median"] / max(decisions, 1) * 1e3
    out["decisions"] = decisions
    out["overhead"] = out["on_s_median"] / out["off_s_median"] - 1.0
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="1,8,32")
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--profile-runs", type=int, default=3)
    ap.add_argument("--adaptive-runs", type=int, default=2)
    ap.add_argument("--fused-sizes", default="32,128,1024",
                    help="fleet sizes for the fused-campaign race "
                         "(empty string skips it)")
    ap.add_argument("--fused-runs", type=int, default=2)
    ap.add_argument("--fused-repeats", type=int, default=5)
    ap.add_argument("--numpy-max", type=int, default=32,
                    help="largest fleet the numpy replay runs for real; "
                         "bigger sizes extrapolate (numpy_estimated)")
    ap.add_argument("--no-fused", dest="fused", action="store_false")
    ap.add_argument("--obs-overhead-max", type=float, default=0.0,
                    help="measure telemetry-on vs telemetry-off fused "
                    "campaign time and fail (exit 1) if the relative "
                    "overhead exceeds this (0 skips the check)")
    ap.add_argument("--budget-s", type=float, default=0.0,
                    help="fail (exit 1) if total wall time exceeds this")
    ap.add_argument("--out", default="BENCH_decision.json")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    t_start = time.time()

    # budget FIRST: it must observe a cold jit cache — running the fleet
    # throughput sweep beforehand would prewarm bucket compiles and hide
    # regressions from the trace counter
    budget = measure_budget(args.adaptive_runs, args.profile_runs)
    print(f"budget,buckets={budget['visited_buckets']},"
          f"compiles={budget['fleet_sweep_compiles']},"
          f"decisions={budget['decisions']},bound={budget['bucket_bound']}")

    base = build_base_experiments(args.profile_runs)
    fleet_rows = measure_fleet(base, sizes, args.repeats)
    for r in fleet_rows:
        print(f"fleet,size={r['fleet_size']},"
              f"seq={r['sequential_dec_per_s']:.1f}/s,"
              f"batched={r['batched_dec_per_s']:.1f}/s,"
              f"speedup={r['speedup']:.2f}x")

    fused_rows: List[Dict] = []
    if args.fused and args.fused_sizes:
        fsizes = tuple(int(s) for s in args.fused_sizes.split(","))
        fused_rows = measure_fused(fsizes, args.fused_runs,
                                   args.fused_repeats, args.profile_runs,
                                   args.numpy_max)
        for r in fused_rows:
            print(f"fused,size={r['fleet_size']},"
                  f"fused={r['fused_s_median']*1e3:.0f}ms,"
                  f"stepped={r['stepped_s_median']*1e3:.0f}ms,"
                  f"live={r['live_s_median']*1e3:.0f}ms,"
                  f"dec_per_s={r['fused_dec_per_s']:.1f},"
                  f"steps_per_s={r['fused_steps_per_s']:.1f},"
                  f"vs_stepped={r['speedup_vs_stepped']:.2f}x,"
                  f"vs_live={r['speedup_vs_live']:.1f}x"
                  + (",live_est" if r["live_estimated"] else ""))

    obs_row: Dict = {}
    if args.obs_overhead_max > 0:
        osize = int(args.fused_sizes.split(",")[0]) if args.fused_sizes \
            else 8
        obs_row = measure_obs_overhead(osize, args.fused_runs,
                                       max(args.fused_repeats, 5),
                                       args.profile_runs)
        print(f"obs_overhead,size={obs_row['fleet_size']},"
              f"off={obs_row['off_s_median'] * 1e3:.0f}ms,"
              f"on={obs_row['on_s_median'] * 1e3:.0f}ms,"
              f"overhead={obs_row['overhead'] * 100:+.1f}%")

    # controller latency distributions (decision dispatch + fit) observed
    # during this bench, from the registry's fixed-bucket histograms
    lat_rows: List[Dict] = []
    if obs.enabled():
        lat_rows = [dict(r, source="fleet_bench")
                    for r in obs.registry().rows()
                    if r["kind"] == "histogram" and r.get("count")]
        for r in lat_rows:
            print(f"latency,{r['metric']},{r['labels']},n={r['count']},"
                  f"p50={r['p50'] * 1e3:.3f}ms,p95={r['p95'] * 1e3:.3f}ms,"
                  f"p99={r['p99'] * 1e3:.3f}ms,max={r['max'] * 1e3:.3f}ms")

    updates = {"fleet": fleet_rows, "fleet_budget": budget}
    if obs_row:
        updates["obs_overhead"] = obs_row
    if fused_rows:
        # merge-by-size so partial reruns (one big fleet at a time) refresh
        # their row without clobbering the others
        prev: Dict = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                prev = {r.get("fleet_size"): r
                        for r in json.load(f).get("fused", [])}
        for r in fused_rows:
            prev[r["fleet_size"]] = r
        updates["fused"] = [prev[k] for k in sorted(prev)]
    merge_bench_json(args.out, updates)
    if lat_rows:
        merge_latency_rows(args.out, lat_rows, "fleet_bench")
    print(f"wrote {os.path.abspath(args.out)}")

    ok = True
    if budget["fleet_sweep_compiles"] > budget["visited_buckets"]:
        print(f"FAIL: {budget['fleet_sweep_compiles']} compiles > "
              f"{budget['visited_buckets']} visited buckets "
              "(recompilation within a bucket)")
        ok = False
    if budget["visited_buckets"] > MAX_BUCKETS:
        print(f"FAIL: campaign visited {budget['visited_buckets']} buckets "
              f"> ladder bound {MAX_BUCKETS}")
        ok = False
    for r in fused_rows:
        if r["new_traces_during_timing"]:
            print(f"FAIL: fused fleet {r['fleet_size']} added "
                  f"{r['new_traces_during_timing']} traces during timed "
                  "repeats (compile count must be bounded by the ladder)")
            ok = False
        if r["nonfinite_decisions"]:
            print(f"FAIL: fused fleet {r['fleet_size']} produced "
                  f"{r['nonfinite_decisions']} non-finite decisions")
            ok = False
    if obs_row and obs_row["overhead"] > args.obs_overhead_max:
        print(f"FAIL: in-scan telemetry overhead "
              f"{obs_row['overhead'] * 100:.1f}% > "
              f"{args.obs_overhead_max * 100:.1f}% "
              f"(fused size {obs_row['fleet_size']})")
        ok = False
    wall = time.time() - t_start
    if args.budget_s and wall > args.budget_s:
        print(f"FAIL: fleet bench took {wall:.0f}s "
              f"> budget {args.budget_s:.0f}s")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
