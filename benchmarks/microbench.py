"""Micro-benchmarks: Pallas kernels (interpret mode — correctness-path
timings, regression tracking only) and per-arch smoke train steps."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, repeats=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / repeats * 1e6    # us


def kernel_benches() -> List[Dict]:
    from repro.kernels.flash_attention.ops import mha
    from repro.kernels.flash_decode.ops import decode_attn
    from repro.kernels.mlstm_chunk.ops import mlstm
    rng = np.random.RandomState(0)
    rows = []
    q = jnp.asarray(rng.randn(1, 256, 4, 64), jnp.float32)
    kv = jnp.asarray(rng.randn(1, 256, 2, 64), jnp.float32)
    rows.append({"name": "kernel_flash_attention_256",
                 "us": _time(lambda: mha(q, kv, kv, block_q=128))})
    qd = jnp.asarray(rng.randn(2, 1, 4, 64), jnp.float32)
    ck = jnp.asarray(rng.randn(2, 512, 2, 64), jnp.float32)
    rows.append({"name": "kernel_flash_decode_512",
                 "us": _time(lambda: decode_attn(qd, ck, ck, jnp.int32(400)))})
    qm = jnp.asarray(rng.randn(1, 256, 2, 64), jnp.float32)
    g = jnp.asarray(rng.randn(1, 256, 2), jnp.float32)
    rows.append({"name": "kernel_mlstm_chunk_256",
                 "us": _time(lambda: mlstm(qm, qm, qm, g, g + 2, chunk=64))})
    return rows


def train_step_benches(archs=("qwen3-0.6b", "olmoe-1b-7b", "xlstm-350m",
                              "jamba-v0.1-52b")) -> List[Dict]:
    from repro.configs import get_config, smoke_config
    from repro.train.optimizer import AdamWConfig
    from repro.train.train import init_train_state, make_train_step
    rows = []
    key = jax.random.PRNGKey(0)
    for arch in archs:
        cfg = smoke_config(get_config(arch))
        opt = AdamWConfig()
        state = init_train_state(key, cfg, opt)
        step = jax.jit(make_train_step(cfg, opt))
        batch = {"tokens": jax.random.randint(key, (2, 32), 0,
                                              cfg.raw_vocab_size),
                 "targets": jax.random.randint(key, (2, 32), 0,
                                               cfg.raw_vocab_size)}
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((2, cfg.enc_frames, cfg.d_model))
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((2, cfg.n_patches, cfg.d_model))

        def run(state=state, batch=batch, step=step):
            s, m = step(state, batch)
            return m["loss"]

        rows.append({"name": f"smoke_train_step_{arch}", "us": _time(run)})
    return rows


def main():
    for r in kernel_benches() + train_step_benches():
        print(f"{r['name']},{r['us']:.0f},interpret_or_smoke")
    return True


if __name__ == "__main__":
    main()
