"""Roofline table from the dry-run artifacts (§Roofline deliverable):
per (arch x shape x mesh): the three time terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS utilization, and hillclimb-cell selection."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_all(mesh: str = "pod1") -> List[Dict]:
    out = []
    for p in sorted(ARTIFACTS.glob(f"*--{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


def render(mesh: str = "pod1") -> str:
    rows = load_all(mesh)
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
             "| useful FLOPs | peak frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        t = r["roofline"]
        bound = max(t.values())
        frac = t["t_compute"] / bound if bound else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute']:.4f} | "
            f"{t['t_memory']:.4f} | {t['t_collective']:.4f} | "
            f"{r['dominant'][2:]} | {r['useful_flops_ratio']:.3f} | "
            f"{frac:.3f} |")
    return "\n".join(lines)


def peak_fraction(r: Dict) -> float:
    """Fraction of the roofline-bound step time spent at peak compute."""
    t = r["roofline"]
    bound = max(t.values())
    return t["t_compute"] / bound if bound > 0 else 0.0


def pick_hillclimb_cells(mesh: str = "pod1") -> Dict[str, Dict]:
    rows = [r for r in load_all(mesh) if r.get("status") == "ok"
            and r["shape"] == "train_4k"]
    worst = min(rows, key=peak_fraction)
    coll = max(rows, key=lambda r: r["roofline"]["t_collective"] /
               max(max(r["roofline"].values()), 1e-12))
    # most representative of the paper: the MoE arch whose elastic re-mesh
    # cost Enel's overhead model targets (largest expert state)
    moe = [r for r in rows if r["arch"] in ("arctic-480b", "olmoe-1b-7b")]
    rep = max(moe, key=lambda r: r["flops_per_device"]) if moe else rows[0]
    return {"worst_fraction": worst, "most_collective": coll,
            "paper_representative": rep}


def main():
    for mesh in ("pod1",):
        rows = load_all(mesh)
        ok = [r for r in rows if r.get("status") == "ok"]
        for r in ok:
            t = r["roofline"]
            print(f"roofline,{r['arch']}--{r['shape']},"
                  f"{max(t.values())*1e6:.0f},"
                  f"dominant={r['dominant']},useful={r['useful_flops_ratio']:.3f}")
    cells = pick_hillclimb_cells()
    for k, r in cells.items():
        print(f"hillclimb,{k},{r['arch']}--{r['shape']}")
    return True


if __name__ == "__main__":
    print(render())
    main()
