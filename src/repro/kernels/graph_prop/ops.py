"""Jit-friendly wrapper: Enel param pytree + bool masks -> fused kernel.

Handles batch padding to the graph-block size, dtype/bias-layout massaging
and the interpret-mode fallback (the CPU backend cannot lower TPU Pallas, so
off-TPU the kernel runs in interpret mode — same semantics, used by tests).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.graph_prop.kernel import graph_prop_kernel


def _row(v: jax.Array) -> jax.Array:
    return jnp.asarray(v, jnp.float32)[None, :]


def graph_prop(params: Dict, x: jax.Array, adj: jax.Array, m_obs: jax.Array,
               valid: jax.Array, *, levels: int = 8, block_g: int = 8,
               interpret: Optional[bool] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """eqs. 6-7 for a stacked batch of padded graphs.

    params: the Enel pytree (uses "f3", "f4", "attn_a"); x: (B,N,X_DIM);
    adj: (B,N,N) bool (already mask-ANDed); m_obs: (B,N,M); valid: (B,N)
    bool.  Returns (e (B,N,N) f32, m_hat (B,N,M) f32).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = x.shape[0]
    gb = min(block_g, b)
    pad = (-b) % gb
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        adj = jnp.concatenate(
            [adj, jnp.zeros((pad,) + adj.shape[1:], adj.dtype)])
        m_obs = jnp.concatenate(
            [m_obs, jnp.zeros((pad,) + m_obs.shape[1:], m_obs.dtype)])
        valid = jnp.concatenate(
            [valid, jnp.zeros((pad,) + valid.shape[1:], valid.dtype)])
    f3, f4 = params["f3"], params["f4"]
    e, m_hat = graph_prop_kernel(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(adj, jnp.float32),
        jnp.asarray(m_obs, jnp.float32),
        jnp.asarray(valid, jnp.float32),
        jnp.asarray(f3[0]["w"], jnp.float32), _row(f3[0]["b"]),
        jnp.asarray(f3[1]["w"], jnp.float32), _row(f3[1]["b"]),
        _row(params["attn_a"]),
        jnp.asarray(f4[0]["w"], jnp.float32), _row(f4[0]["b"]),
        jnp.asarray(f4[1]["w"], jnp.float32), _row(f4[1]["b"]),
        levels=levels, block_g=gb, interpret=interpret)
    return e[:b], m_hat[:b]
