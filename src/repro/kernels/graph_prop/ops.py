"""Jit-friendly wrapper: Enel param pytree + bool masks -> fused kernel.

Handles batch padding to the graph-block size, dtype/bias-layout massaging
and the interpret-mode fallback (the CPU backend cannot lower TPU Pallas, so
off-TPU the kernel runs in interpret mode — same semantics, used by tests).

The wrapped op carries a ``jax.custom_vjp``: the backward pass is a second
Pallas kernel (:func:`repro.kernels.graph_prop.kernel.graph_prop_bwd_kernel`)
that recomputes the edge hiddens in VMEM and propagates cotangents back
through the level-synchronous loop, so training (``enel_loss`` /
``forward_stacked(use_kernel=True)``) can differentiate straight through the
fused path instead of being pinned to the inline ``vmap(forward)`` route.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.graph_prop.kernel import (graph_prop_bwd_kernel,
                                             graph_prop_kernel)


def _row(v: jax.Array) -> jax.Array:
    return jnp.asarray(v, jnp.float32)[None, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _graph_prop_core(levels, block_g, interpret, x, adj, m_obs, valid,
                     w31, b31, w32, b32, attn, w41, b41, w42, b42):
    """Differentiable core over already-padded float32 arrays.

    ``adj``/``valid`` are 0/1 float masks at this level so the custom VJP can
    hand back ordinary (zero) cotangents for them.
    """
    return graph_prop_kernel(x, adj, m_obs, valid, w31, b31, w32, b32, attn,
                             w41, b41, w42, b42, levels=levels,
                             block_g=block_g, interpret=interpret)


def _core_fwd(levels, block_g, interpret, *args):
    out = _graph_prop_core(levels, block_g, interpret, *args)
    return out, args


def _core_bwd(levels, block_g, interpret, res, cots):
    (x, adj, m_obs, valid, w31, b31, w32, b32, attn, w41, b41, w42, b42) = res
    g_e, g_mhat = cots
    (gx, gmo, gw31, gb31, gw32, gb32, ga, gw41, gb41, gw42, gb42) = \
        graph_prop_bwd_kernel(x, adj, m_obs, valid, w31, b31, w32, b32, attn,
                              w41, b41, w42, b42, g_e, g_mhat, levels=levels,
                              block_g=block_g, interpret=interpret)
    return (gx, jnp.zeros_like(adj), gmo, jnp.zeros_like(valid),
            gw31, gb31, gw32, gb32, ga, gw41, gb41, gw42, gb42)


_graph_prop_core.defvjp(_core_fwd, _core_bwd)


def graph_prop(params: Dict, x: jax.Array, adj: jax.Array, m_obs: jax.Array,
               valid: jax.Array, *, levels: int = 8, block_g: int = 8,
               interpret: Optional[bool] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """eqs. 6-7 for a stacked batch of padded graphs.

    params: the Enel pytree (uses "f3", "f4", "attn_a"); x: (B,N,X_DIM);
    adj: (B,N,N) bool (already mask-ANDed); m_obs: (B,N,M); valid: (B,N)
    bool.  Returns (e (B,N,N) f32, m_hat (B,N,M) f32).  Differentiable in
    ``params``, ``x`` and ``m_obs`` via the backward Pallas kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = x.shape[0]
    gb = min(block_g, b)
    pad = (-b) % gb
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        adj = jnp.concatenate(
            [adj, jnp.zeros((pad,) + adj.shape[1:], adj.dtype)])
        m_obs = jnp.concatenate(
            [m_obs, jnp.zeros((pad,) + m_obs.shape[1:], m_obs.dtype)])
        valid = jnp.concatenate(
            [valid, jnp.zeros((pad,) + valid.shape[1:], valid.dtype)])
    f3, f4 = params["f3"], params["f4"]
    e, m_hat = _graph_prop_core(
        levels, gb, interpret,
        jnp.asarray(x, jnp.float32),
        jnp.asarray(adj, jnp.float32),
        jnp.asarray(m_obs, jnp.float32),
        jnp.asarray(valid, jnp.float32),
        jnp.asarray(f3[0]["w"], jnp.float32), _row(f3[0]["b"]),
        jnp.asarray(f3[1]["w"], jnp.float32), _row(f3[1]["b"]),
        _row(params["attn_a"]),
        jnp.asarray(f4[0]["w"], jnp.float32), _row(f4[0]["b"]),
        jnp.asarray(f4[1]["w"], jnp.float32), _row(f4[1]["b"]))
    return e[:b], m_hat[:b]
