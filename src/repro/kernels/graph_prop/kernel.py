"""Fused Enel graph-propagation (eqs. 6-7) as a Pallas TPU kernel.

One kernel instance handles a block of G padded component graphs: the dense
N x N f3 edge MLP, the predecessor-masked softmax and all ``levels`` rounds
of f4 metric message passing run fused in VMEM — no HBM round-trips for the
(G, N, N, EDGE_DIM) edge activations between the stages, which is where the
XLA path spends its bandwidth.  Pair features are flattened to (G*N*N, 2*XD)
so every MLP layer is a single MXU matmul.

VMEM at G=8, N=16 (MAX_NODES), XD=30, E=16: pair features ~1 MB f32 peak —
far inside the ~16 MB/core budget; grid is 1-D over graph blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, adj_ref, m_ref, valid_ref,
            w31_ref, b31_ref, w32_ref, b32_ref, attn_ref,
            w41_ref, b41_ref, w42_ref, b42_ref,
            e_ref, mh_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)                  # (G, N, XD)
    g, n, xd = x.shape
    adj = adj_ref[...].astype(jnp.float32)              # (G, N, N) 0/1
    m_obs = m_ref[...].astype(jnp.float32)              # (G, N, M)
    nm = m_obs.shape[-1]
    valid = valid_ref[...].astype(jnp.float32)[..., None]   # (G, N, 1)

    # eq.6 — f3 on all (dst i, src j) pairs, one MXU matmul per layer
    xi = jnp.broadcast_to(x[:, :, None, :], (g, n, n, xd))
    xj = jnp.broadcast_to(x[:, None, :, :], (g, n, n, xd))
    pair = jnp.concatenate([xi, xj], axis=-1).reshape(g * n * n, 2 * xd)
    h = jax.nn.leaky_relu(pair @ w31_ref[...] + b31_ref[...][0], 0.1)
    h3 = h @ w32_ref[...] + b32_ref[...][0]             # (G*N*N, E)
    logits = (jax.nn.leaky_relu(h3, 0.1)
              @ attn_ref[...][0][:, None])[:, 0].reshape(g, n, n)
    logits = jnp.where(adj > 0, logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits - mx)
    sm = ex / jnp.sum(ex, axis=-1, keepdims=True)
    has_pred = jnp.sum(adj, axis=-1, keepdims=True) > 0
    e = jnp.where(has_pred, sm, 0.0)                    # (G, N, N)
    e_ref[...] = e.astype(e_ref.dtype)

    # eq.7 — level-synchronous metric propagation, h3 stays resident.  f4's
    # first layer is split: the h3 @ W_h half is level-invariant and runs
    # once; per level only the small metric half is recomputed.
    ed = h3.shape[-1]
    w41 = w41_ref[...]
    pre_h = (h3 @ w41[:ed]).reshape(g, n, n, -1)        # (G, N, N, HIDDEN)
    w_m = w41[ed:]                                      # (M, HIDDEN)
    b41 = b41_ref[...][0]

    def level_step(_, m_cur):
        mj = jnp.where(valid > 0, m_obs, m_cur)         # (G, N, M)
        mh = (mj.reshape(g * n, nm) @ w_m).reshape(g, 1, n, -1)
        hh = jax.nn.leaky_relu(pre_h + mh + b41, 0.1)
        msg = (hh.reshape(g * n * n, -1) @ w42_ref[...]
               + b42_ref[...][0]).reshape(g, n, n, nm)
        m_prop = jnp.sum(e[..., None] * msg, axis=2)
        return jnp.where(valid > 0, m_obs, m_prop)

    m_hat = jax.lax.fori_loop(0, levels, level_step, m_obs)
    mh_ref[...] = m_hat.astype(mh_ref.dtype)


def graph_prop_kernel(x: jax.Array, adj: jax.Array, m_obs: jax.Array,
                      valid: jax.Array, f3w1, f3b1, f3w2, f3b2, attn_a,
                      f4w1, f4b1, f4w2, f4b2, *, levels: int = 8,
                      block_g: int = 8, interpret: bool = True):
    """x: (B,N,XD) f32; adj: (B,N,N) 0/1 f32; m_obs: (B,N,M); valid: (B,N)
    f32.  Biases/attention come in as (1, dim) rows.  B must be a multiple
    of ``block_g`` (ops.py pads).  Returns (e (B,N,N), m_hat (B,N,M))."""
    b, n, xd = x.shape
    nm = m_obs.shape[-1]
    gb = min(block_g, b)
    assert b % gb == 0, (b, gb)
    hid = f3w1.shape[1]
    ed = f3w2.shape[1]
    kernel = functools.partial(_kernel, levels=levels)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: (0,) * len(dims))
    e, m_hat = pl.pallas_call(
        kernel,
        grid=(b // gb,),
        in_specs=[
            pl.BlockSpec((gb, n, xd), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, nm), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n), lambda i: (i, 0)),
            full(2 * xd, hid), full(1, hid), full(hid, ed), full(1, ed),
            full(1, ed), full(ed + nm, hid), full(1, hid), full(hid, nm),
            full(1, nm),
        ],
        out_specs=[
            pl.BlockSpec((gb, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, nm), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n, nm), jnp.float32),
        ],
        interpret=interpret,
    )(x, adj, m_obs, valid, f3w1, f3b1, f3w2, f3b2, attn_a,
      f4w1, f4b1, f4w2, f4b2)
    return e, m_hat
