"""Fused Enel graph-propagation (eqs. 6-7) as a Pallas TPU kernel.

One kernel instance handles a block of G padded component graphs: the dense
N x N f3 edge MLP, the predecessor-masked softmax and all ``levels`` rounds
of f4 metric message passing run fused in VMEM — no HBM round-trips for the
(G, N, N, EDGE_DIM) edge activations between the stages, which is where the
XLA path spends its bandwidth.  Pair features are flattened to (G*N*N, 2*XD)
so every MLP layer is a single MXU matmul.

VMEM at G=8, N=16 (MAX_NODES), XD=30, E=16: pair features ~1 MB f32 peak —
far inside the ~16 MB/core budget; grid is 1-D over graph blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, adj_ref, m_ref, valid_ref,
            w31_ref, b31_ref, w32_ref, b32_ref, attn_ref,
            w41_ref, b41_ref, w42_ref, b42_ref,
            e_ref, mh_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)                  # (G, N, XD)
    g, n, xd = x.shape
    adj = adj_ref[...].astype(jnp.float32)              # (G, N, N) 0/1
    m_obs = m_ref[...].astype(jnp.float32)              # (G, N, M)
    nm = m_obs.shape[-1]
    valid = valid_ref[...].astype(jnp.float32)[..., None]   # (G, N, 1)

    # eq.6 — f3 on all (dst i, src j) pairs, one MXU matmul per layer
    xi = jnp.broadcast_to(x[:, :, None, :], (g, n, n, xd))
    xj = jnp.broadcast_to(x[:, None, :, :], (g, n, n, xd))
    pair = jnp.concatenate([xi, xj], axis=-1).reshape(g * n * n, 2 * xd)
    h = jax.nn.leaky_relu(pair @ w31_ref[...] + b31_ref[...][0], 0.1)
    h3 = h @ w32_ref[...] + b32_ref[...][0]             # (G*N*N, E)
    logits = (jax.nn.leaky_relu(h3, 0.1)
              @ attn_ref[...][0][:, None])[:, 0].reshape(g, n, n)
    logits = jnp.where(adj > 0, logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits - mx)
    sm = ex / jnp.sum(ex, axis=-1, keepdims=True)
    has_pred = jnp.sum(adj, axis=-1, keepdims=True) > 0
    e = jnp.where(has_pred, sm, 0.0)                    # (G, N, N)
    e_ref[...] = e.astype(e_ref.dtype)

    # eq.7 — level-synchronous metric propagation, h3 stays resident.  f4's
    # first layer is split: the h3 @ W_h half is level-invariant and runs
    # once; per level only the small metric half is recomputed.
    ed = h3.shape[-1]
    w41 = w41_ref[...]
    pre_h = (h3 @ w41[:ed]).reshape(g, n, n, -1)        # (G, N, N, HIDDEN)
    w_m = w41[ed:]                                      # (M, HIDDEN)
    b41 = b41_ref[...][0]

    def level_step(_, m_cur):
        mj = jnp.where(valid > 0, m_obs, m_cur)         # (G, N, M)
        mh = (mj.reshape(g * n, nm) @ w_m).reshape(g, 1, n, -1)
        hh = jax.nn.leaky_relu(pre_h + mh + b41, 0.1)
        msg = (hh.reshape(g * n * n, -1) @ w42_ref[...]
               + b42_ref[...][0]).reshape(g, n, n, nm)
        m_prop = jnp.sum(e[..., None] * msg, axis=2)
        return jnp.where(valid > 0, m_obs, m_prop)

    m_hat = jax.lax.fori_loop(0, levels, level_step, m_obs)
    mh_ref[...] = m_hat.astype(mh_ref.dtype)


def _dleaky(z: jax.Array, slope: float = 0.1) -> jax.Array:
    """d/dz leaky_relu(z, slope) with jax.nn.leaky_relu's z == 0 convention."""
    return jnp.where(z >= 0, 1.0, slope)


def _bwd_kernel(x_ref, adj_ref, m_ref, valid_ref,
                w31_ref, b31_ref, w32_ref, b32_ref, attn_ref,
                w41_ref, b41_ref, w42_ref, b42_ref,
                ge_ref, gm_ref,
                gx_ref, gmo_ref, gw31_ref, gb31_ref, gw32_ref, gb32_ref,
                ga_ref, gw41_ref, gb41_ref, gw42_ref, gb42_ref,
                *, levels: int):
    """Reverse-mode twin of :func:`_kernel`.

    Recomputes the forward edge hiddens / softmax / level states in VMEM
    (nothing but the primal inputs is saved between fwd and bwd), then
    propagates the (e, m_hat) cotangents back through the level-synchronous
    loop and the f3/f4 MLPs.  Per-graph-block parameter gradients go to a
    per-block output slot; the wrapper sums them over the grid axis.
    """
    x = x_ref[...].astype(jnp.float32)                  # (G, N, XD)
    g, n, xd = x.shape
    adj = adj_ref[...].astype(jnp.float32)              # (G, N, N) 0/1
    m_obs = m_ref[...].astype(jnp.float32)              # (G, N, M)
    nm = m_obs.shape[-1]
    valid = valid_ref[...].astype(jnp.float32)[..., None]   # (G, N, 1)
    w31, w32 = w31_ref[...], w32_ref[...]
    b31, b32 = b31_ref[...][0], b32_ref[...][0]
    a_row = attn_ref[...]                               # (1, E)
    w41, b41 = w41_ref[...], b41_ref[...][0]
    w42, b42 = w42_ref[...], b42_ref[...][0]
    hid = w31.shape[1]
    ed = w32.shape[1]

    # ---- forward recompute: f3, masked softmax, split f4 first layer
    xi = jnp.broadcast_to(x[:, :, None, :], (g, n, n, xd))
    xj = jnp.broadcast_to(x[:, None, :, :], (g, n, n, xd))
    pair = jnp.concatenate([xi, xj], axis=-1).reshape(g * n * n, 2 * xd)
    z1 = pair @ w31 + b31
    h1 = jax.nn.leaky_relu(z1, 0.1)
    h3 = h1 @ w32 + b32                                 # (G*N*N, E)
    lrel = jax.nn.leaky_relu(h3, 0.1)
    logits = (lrel @ a_row[0][:, None])[:, 0].reshape(g, n, n)
    logits = jnp.where(adj > 0, logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits - mx)
    sm = ex / jnp.sum(ex, axis=-1, keepdims=True)
    has_pred = jnp.sum(adj, axis=-1, keepdims=True) > 0
    e = jnp.where(has_pred, sm, 0.0)                    # (G, N, N)
    pre_h = (h3 @ w41[:ed]).reshape(g, n, n, hid)
    w_m = w41[ed:]                                      # (M, HIDDEN)

    # ---- forward level loop again, stashing each level's INPUT state m^t
    def fwd_level(t, carry):
        m_cur, ms = carry
        ms = jax.lax.dynamic_update_slice(ms, m_cur[None], (t, 0, 0, 0))
        mj = jnp.where(valid > 0, m_obs, m_cur)
        mh = (mj.reshape(g * n, nm) @ w_m).reshape(g, 1, n, hid)
        hh = jax.nn.leaky_relu(pre_h + mh + b41, 0.1)
        msg = (hh.reshape(g * n * n, hid) @ w42 + b42).reshape(g, n, n, nm)
        m_prop = jnp.sum(e[..., None] * msg, axis=2)
        return jnp.where(valid > 0, m_obs, m_prop), ms

    ms0 = jnp.zeros((levels, g, n, nm), jnp.float32)
    _, ms = jax.lax.fori_loop(0, levels, fwd_level, (m_obs, ms0))

    # ---- reverse sweep through the level loop
    def bwd_level(i, carry):
        (g_m, g_mo, g_e, g_preh, g_wm, g_b41, g_w42, g_b42) = carry
        t = levels - 1 - i
        m_cur = jax.lax.dynamic_slice(ms, (t, 0, 0, 0), (1, g, n, nm))[0]
        mj = jnp.where(valid > 0, m_obs, m_cur)
        mh = (mj.reshape(g * n, nm) @ w_m).reshape(g, 1, n, hid)
        zz = pre_h + mh + b41
        hh = jax.nn.leaky_relu(zz, 0.1)
        msg = (hh.reshape(g * n * n, hid) @ w42 + b42).reshape(g, n, n, nm)
        # m_next = where(valid, m_obs, sum_j e * msg)
        g_mo = g_mo + valid * g_m
        g_prop = (1.0 - valid) * g_m                      # (G, N, M)
        g_e = g_e + jnp.sum(g_prop[:, :, None, :] * msg, axis=-1)
        g_msg = (e[..., None] * g_prop[:, :, None, :]).reshape(g * n * n, nm)
        g_w42 = g_w42 + hh.reshape(g * n * n, hid).T @ g_msg
        g_b42 = g_b42 + jnp.sum(g_msg, axis=0, keepdims=True)
        g_zz = (g_msg @ w42.T).reshape(g, n, n, hid) * _dleaky(zz)
        g_preh = g_preh + g_zz
        g_b41 = g_b41 + jnp.sum(g_zz.reshape(g * n * n, hid), axis=0,
                                keepdims=True)
        g_mh = jnp.sum(g_zz, axis=1).reshape(g * n, hid)  # bcast over dst i
        g_wm = g_wm + mj.reshape(g * n, nm).T @ g_mh
        g_mj = (g_mh @ w_m.T).reshape(g, n, nm)
        g_mo = g_mo + valid * g_mj
        return (1.0 - valid) * g_mj, g_mo, g_e, g_preh, g_wm, g_b41, \
            g_w42, g_b42

    zero = jnp.zeros
    (g_m, g_mo, g_e_acc, g_preh, g_wm, g_b41, g_w42, g_b42) = \
        jax.lax.fori_loop(0, levels, bwd_level, (
            gm_ref[...].astype(jnp.float32),
            zero((g, n, nm), jnp.float32),
            zero((g, n, n), jnp.float32),
            zero((g, n, n, hid), jnp.float32),
            zero((nm, hid), jnp.float32),
            zero((1, hid), jnp.float32),
            zero((hid, nm), jnp.float32),
            zero((1, nm), jnp.float32)))
    g_mo = g_mo + g_m                                    # m^0 == m_obs

    # ---- masked softmax + attention readout backward
    g_e = ge_ref[...].astype(jnp.float32) + g_e_acc
    g_sm = jnp.where(has_pred, g_e, 0.0)
    g_logits = sm * (g_sm - jnp.sum(sm * g_sm, axis=-1, keepdims=True))
    g_logits = jnp.where(adj > 0, g_logits, 0.0).reshape(g * n * n)
    ga_ref[...] = (g_logits[None, :] @ lrel)[None].astype(ga_ref.dtype)
    g_h3 = g_logits[:, None] * a_row * _dleaky(h3)
    g_preh_f = g_preh.reshape(g * n * n, hid)
    g_h3 = g_h3 + g_preh_f @ w41[:ed].T
    gw41_ref[...] = jnp.concatenate(
        [h3.T @ g_preh_f, g_wm], axis=0)[None].astype(gw41_ref.dtype)
    gb41_ref[...] = g_b41[None].astype(gb41_ref.dtype)
    gw42_ref[...] = g_w42[None].astype(gw42_ref.dtype)
    gb42_ref[...] = g_b42[None].astype(gb42_ref.dtype)

    # ---- f3 MLP backward
    gw32_ref[...] = (h1.T @ g_h3)[None].astype(gw32_ref.dtype)
    gb32_ref[...] = jnp.sum(g_h3, axis=0, keepdims=True)[None].astype(
        gb32_ref.dtype)
    g_z1 = (g_h3 @ w32.T) * _dleaky(z1)
    gw31_ref[...] = (pair.T @ g_z1)[None].astype(gw31_ref.dtype)
    gb31_ref[...] = jnp.sum(g_z1, axis=0, keepdims=True)[None].astype(
        gb31_ref.dtype)
    g_pair = (g_z1 @ w31.T).reshape(g, n, n, 2 * xd)
    gx_ref[...] = (jnp.sum(g_pair[..., :xd], axis=2) +
                   jnp.sum(g_pair[..., xd:], axis=1)).astype(gx_ref.dtype)
    gmo_ref[...] = g_mo.astype(gmo_ref.dtype)


def graph_prop_bwd_kernel(x, adj, m_obs, valid, f3w1, f3b1, f3w2, f3b2,
                          attn_a, f4w1, f4b1, f4w2, f4b2, g_e, g_mhat, *,
                          levels: int = 8, block_g: int = 8,
                          interpret: bool = True):
    """VJP of :func:`graph_prop_kernel` w.r.t. (x, m_obs, params).

    Same layout contract as the forward kernel; ``g_e``/``g_mhat`` are the
    output cotangents.  Returns ``(gx, gm_obs, gw31, gb31, gw32, gb32, ga,
    gw41, gb41, gw42, gb42)`` with biases/attention as (1, dim) rows —
    parameter gradients are summed over graph blocks here, outside pallas.
    """
    b, n, xd = x.shape
    nm = m_obs.shape[-1]
    gb = min(block_g, b)
    assert b % gb == 0, (b, gb)
    nb = b // gb
    hid = f3w1.shape[1]
    ed = f3w2.shape[1]
    kernel = functools.partial(_bwd_kernel, levels=levels)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: (0,) * len(dims))
    slot = lambda *dims: pl.BlockSpec((1,) + dims,
                                      lambda i: (i,) + (0,) * len(dims))
    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((gb, n, xd), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, nm), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n), lambda i: (i, 0)),
            full(2 * xd, hid), full(1, hid), full(hid, ed), full(1, ed),
            full(1, ed), full(ed + nm, hid), full(1, hid), full(hid, nm),
            full(1, nm),
            pl.BlockSpec((gb, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, nm), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((gb, n, xd), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, nm), lambda i: (i, 0, 0)),
            slot(2 * xd, hid), slot(1, hid), slot(hid, ed), slot(1, ed),
            slot(1, ed), slot(ed + nm, hid), slot(1, hid), slot(hid, nm),
            slot(1, nm),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n, xd), jnp.float32),
            jax.ShapeDtypeStruct((b, n, nm), jnp.float32),
            jax.ShapeDtypeStruct((nb, 2 * xd, hid), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1, hid), jnp.float32),
            jax.ShapeDtypeStruct((nb, hid, ed), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1, ed), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1, ed), jnp.float32),
            jax.ShapeDtypeStruct((nb, ed + nm, hid), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1, hid), jnp.float32),
            jax.ShapeDtypeStruct((nb, hid, nm), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1, nm), jnp.float32),
        ],
        interpret=interpret,
    )(x, adj, m_obs, valid, f3w1, f3b1, f3w2, f3b2, attn_a,
      f4w1, f4b1, f4w2, f4b2, g_e, g_mhat)
    gx, gmo = outs[0], outs[1]
    return (gx, gmo) + tuple(o.sum(axis=0) for o in outs[2:])


def graph_prop_kernel(x: jax.Array, adj: jax.Array, m_obs: jax.Array,
                      valid: jax.Array, f3w1, f3b1, f3w2, f3b2, attn_a,
                      f4w1, f4b1, f4w2, f4b2, *, levels: int = 8,
                      block_g: int = 8, interpret: bool = True):
    """x: (B,N,XD) f32; adj: (B,N,N) 0/1 f32; m_obs: (B,N,M); valid: (B,N)
    f32.  Biases/attention come in as (1, dim) rows.  B must be a multiple
    of ``block_g`` (ops.py pads).  Returns (e (B,N,N), m_hat (B,N,M))."""
    b, n, xd = x.shape
    nm = m_obs.shape[-1]
    gb = min(block_g, b)
    assert b % gb == 0, (b, gb)
    hid = f3w1.shape[1]
    ed = f3w2.shape[1]
    kernel = functools.partial(_kernel, levels=levels)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: (0,) * len(dims))
    e, m_hat = pl.pallas_call(
        kernel,
        grid=(b // gb,),
        in_specs=[
            pl.BlockSpec((gb, n, xd), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, nm), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n), lambda i: (i, 0)),
            full(2 * xd, hid), full(1, hid), full(hid, ed), full(1, ed),
            full(1, ed), full(ed + nm, hid), full(1, hid), full(hid, nm),
            full(1, nm),
        ],
        out_specs=[
            pl.BlockSpec((gb, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, n, nm), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n, nm), jnp.float32),
        ],
        interpret=interpret,
    )(x, adj, m_obs, valid, f3w1, f3b1, f3w2, f3b2, attn_a,
      f4w1, f4b1, f4w2, f4b2)
    return e, m_hat
