"""Pure-numpy oracle for the fused graph-propagation kernel (eqs. 6-7).

Defines the semantics the Pallas kernel must reproduce: the dense N x N f3
edge MLP, the predecessor-masked softmax, and ``levels`` rounds of
level-synchronous f4 metric message passing with observed metrics pinned.

:func:`graph_prop_ref_jnp` is the same math in differentiable jnp — its
``jax.grad`` is the oracle for the backward Pallas kernel / custom VJP.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def _leaky(x: np.ndarray, slope: float = 0.1) -> np.ndarray:
    return np.where(x > 0, x, slope * x)


def _mlp_np(layers, x: np.ndarray) -> np.ndarray:
    for li, l in enumerate(layers):
        x = x @ np.asarray(l["w"], np.float32) + np.asarray(l["b"], np.float32)
        if li < len(layers) - 1:
            x = _leaky(x)
    return x


def graph_prop_ref(params: Dict, x: np.ndarray, adj: np.ndarray,
                   m_obs: np.ndarray, valid: np.ndarray,
                   levels: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """x: (B,N,XD); adj: (B,N,N) bool, adj[b,i,j]: j -> i; m_obs: (B,N,M);
    valid: (B,N) bool.  Returns (e (B,N,N), m_hat (B,N,M))."""
    x = np.asarray(x, np.float32)
    adj = np.asarray(adj, bool)
    m_obs = np.asarray(m_obs, np.float32)
    valid = np.asarray(valid, bool)
    b, n, _ = x.shape
    m = m_obs.shape[-1]

    xi = np.broadcast_to(x[:, :, None, :], (b, n, n, x.shape[-1]))
    xj = np.broadcast_to(x[:, None, :, :], (b, n, n, x.shape[-1]))
    h3 = _mlp_np(params["f3"], np.concatenate([xi, xj], axis=-1))
    logits = _leaky(h3) @ np.asarray(params["attn_a"], np.float32)
    logits = np.where(adj, logits, -1e30)
    mx = logits.max(axis=-1, keepdims=True)
    ex = np.exp(logits - mx)
    sm = ex / ex.sum(axis=-1, keepdims=True)
    e = np.where(adj.any(axis=-1, keepdims=True), sm, 0.0).astype(np.float32)

    m_cur = m_obs
    for _ in range(levels):
        mj = np.where(valid[:, :, None], m_obs, m_cur)
        f4_in = np.concatenate(
            [h3, np.broadcast_to(mj[:, None, :, :], (b, n, n, m))], axis=-1)
        msg = _mlp_np(params["f4"], f4_in)
        m_prop = np.einsum("bij,bijm->bim", e, msg)
        m_cur = np.where(valid[:, :, None], m_obs, m_prop)
    return e, m_cur.astype(np.float32)


def graph_prop_ref_jnp(params: Dict, x, adj, m_obs, valid, levels: int = 8):
    """Differentiable jnp mirror of :func:`graph_prop_ref` (same shapes).

    Gradient oracle for the custom-VJP/backward-kernel path: tests compare
    ``jax.grad`` through this against the fused op's VJP.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(x, jnp.float32)
    adj = jnp.asarray(adj, bool)
    m_obs = jnp.asarray(m_obs, jnp.float32)
    valid = jnp.asarray(valid, bool)
    b, n, _ = x.shape
    m = m_obs.shape[-1]

    def mlp(layers, v, final_linear=True):
        for li, l in enumerate(layers):
            v = v @ l["w"] + l["b"]
            if li < len(layers) - 1 or not final_linear:
                v = jax.nn.leaky_relu(v, 0.1)
        return v

    xi = jnp.broadcast_to(x[:, :, None, :], (b, n, n, x.shape[-1]))
    xj = jnp.broadcast_to(x[:, None, :, :], (b, n, n, x.shape[-1]))
    h3 = mlp(params["f3"], jnp.concatenate([xi, xj], axis=-1))
    logits = jax.nn.leaky_relu(h3, 0.1) @ params["attn_a"]
    logits = jnp.where(adj, logits, -1e30)
    sm = jax.nn.softmax(logits, axis=-1)
    e = jnp.where(adj.any(axis=-1, keepdims=True), sm, 0.0)

    def level_step(_, m_cur):
        mj = jnp.where(valid[:, :, None], m_obs, m_cur)
        f4_in = jnp.concatenate(
            [h3, jnp.broadcast_to(mj[:, None, :, :], (b, n, n, m))], axis=-1)
        msg = mlp(params["f4"], f4_in)
        m_prop = jnp.einsum("bij,bijm->bim", e, msg)
        return jnp.where(valid[:, :, None], m_obs, m_prop)

    m_hat = jax.lax.fori_loop(0, levels, level_step, m_obs)
    return e, m_hat
