"""Jit'd wrapper for cache-layout decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import flash_decode


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attn(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                pos: jax.Array, *, window: int = 0, block_k: int = 128,
                interpret: bool = True) -> jax.Array:
    """q: (B, 1, H, D); cache_{k,v}: (B, S, Kh, D) -> (B, 1, H, D)."""
    b, _, h, d = q.shape
    out = flash_decode(q[:, 0], cache_k.transpose(0, 2, 1, 3),
                       cache_v.transpose(0, 2, 1, 3), pos, window=window,
                       block_k=block_k, interpret=interpret)
    return out[:, None]
