"""Flash-decode: one-token attention against a long KV cache.

Grid (batch, q_head, kv_blocks); the KV block axis is innermost/sequential,
carrying the partial-softmax state (m, l, acc) in VMEM scratch — the classic
split-K decode kernel adapted to TPU grid semantics.  The current decode
position arrives as a (1, 1) i32 operand so block (kv > pos) contributions
are masked; on real TPUs this would live in SMEM via scalar prefetch, which
changes none of the math validated here.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            sm_scale: float, window: int, bk: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32)            # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T) * sm_scale                       # (1, bk)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = k_pos <= pos
    if window:
        mask &= k_pos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + (p @ v)[0]
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, *,
                 window: int = 0, block_k: int = 128,
                 interpret: bool = True) -> jax.Array:
    """q: (B, H, D); k, v: (B, Kh, S, D); pos: scalar i32.
    Returns (B, H, D) = softmax over cache positions <= pos."""
    b, h, d = q.shape
    _, kh, sk, _ = k.shape
    group = h // kh
    bk = min(block_k, sk)
    assert sk % bk == 0
    nk = sk // bk
    kernel = functools.partial(_kernel, sm_scale=1.0 / math.sqrt(d),
                               window=window, bk=bk, nk=nk)
    pos_arr = jnp.reshape(pos.astype(jnp.int32), (1, 1))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, k_: (0, 0)),
            pl.BlockSpec((1, 1, d), lambda b_, h_, k_: (b_, h_, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, k_, g=group: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, k_, g=group: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h_, k_: (b_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((), jnp.float32),
            pltpu.VMEM((), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k, v)
