"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def decode_ref(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, *,
               window: int = 0) -> jax.Array:
    """q: (B, H, D); k, v: (B, Kh, S, D); pos scalar -> (B, H, D)."""
    b, h, d = q.shape
    _, kh, sk, _ = k.shape
    group = h // kh
    k = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    v = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k) / math.sqrt(d)
    kp = jnp.arange(sk)
    mask = kp <= pos
    if window:
        mask &= kp > pos - window
    s = jnp.where(mask[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, v).astype(q.dtype)
