"""Jit'd wrapper producing the kernel inputs from raw Mamba quantities."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.kernel import mamba_scan


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(dt: jax.Array, a: jax.Array, x: jax.Array, b: jax.Array,
                   c: jax.Array, *, chunk: int = 64, block_d: int = 128,
                   interpret: bool = True) -> jax.Array:
    """dt: (B,S,D) softplus'd; a: (D,N) negative; x: (B,S,D); b,c: (B,S,N).
    Returns y: (B,S,D) = the SSM output (without the D*x skip term)."""
    decay = jnp.exp(dt[..., None] * a)                        # (B,S,D,N)
    drive = (dt * x)[..., None] * b[:, :, None, :]
    return mamba_scan(decay, drive, c, chunk=chunk, block_d=block_d,
                      interpret=interpret)
