"""Mamba selective scan as a chunkwise Pallas TPU kernel.

Recurrence: h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t * B_t;  y_t = h_t·C_t.
Grid (batch, d_inner blocks, chunks) with the chunk axis innermost; the
carried state h (Bd x N) lives in VMEM scratch across chunks.  Within a
chunk the prefix decays are built with a cumulative-log trick and the
cross-step mixing uses a (L x L) lower-triangular decay matmul per state
column — MXU-friendly, mirrors the associative-scan semantics of
``repro.models.ssm.mamba_forward`` exactly (that function is the oracle's
basis; see ref.py for the strict per-step reference).

VMEM at Bd=128 (d_inner block), N=16, L=64: decay/drive (L,Bd,N) f32
~520 KB + h (Bd,N) — comfortably inside v5e's ~128 MB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(decay_ref, drive_ref, c_ref, o_ref, h_ref, *, L: int, n: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = decay_ref[0].astype(jnp.float32)            # (L, Bd, N) decay
    b = drive_ref[0].astype(jnp.float32)            # (L, Bd, N) drive
    cc = c_ref[0].astype(jnp.float32)               # (L, N)

    # prefix products P_t = prod_{s<=t} a_s via cumulative logs (a in (0,1])
    loga = jnp.log(jnp.maximum(a, 1e-37))
    cum = jnp.cumsum(loga, axis=0)                  # (L, Bd, N)
    P = jnp.exp(cum)
    # h_t = P_t * h0 + P_t * sum_{s<=t} b_s / P_s
    ratio = b * jnp.exp(-cum)
    acc = jnp.cumsum(ratio, axis=0)
    h_all = P * (h_ref[...][None] + acc)            # (L, Bd, N)
    y = jnp.einsum("lbn,ln->lb", h_all, cc)
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype).T
    h_ref[...] = h_all[L - 1]


def mamba_scan(decay: jax.Array, drive: jax.Array, c: jax.Array, *,
               chunk: int = 64, block_d: int = 128,
               interpret: bool = True) -> jax.Array:
    """decay, drive: (B, S, D, N); c: (B, S, N). Returns y: (B, S, D).

    NOTE: the cumulative-log formulation assumes decay > 0 (true for
    exp(dt*A) with A < 0); underflow clamps at 1e-37.
    """
    bsz, s, d, n = decay.shape
    L = min(chunk, s)
    bd = min(block_d, d)
    assert s % L == 0 and d % bd == 0
    nc, nd = s // L, d // bd
    kernel = functools.partial(_kernel, L=L, n=n)
    # layouts: (B, S, D, N) blocks (1, L, bd, N); y (B, D, S) -> transpose out
    out = pl.pallas_call(
        kernel,
        grid=(bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, L, bd, n), lambda b_, d_, c_: (b_, c_, d_, 0)),
            pl.BlockSpec((1, L, bd, n), lambda b_, d_, c_: (b_, c_, d_, 0)),
            pl.BlockSpec((1, L, n), lambda b_, d_, c_: (b_, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, 1, L), lambda b_, d_, c_: (b_, d_, 0, c_)),
        out_shape=jax.ShapeDtypeStruct((bsz, d, 1, s), decay.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(decay, drive, c)
    return out[:, :, 0, :].transpose(0, 2, 1)       # (B, S, D)
