"""Strict per-step oracle for the Mamba selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(decay: jax.Array, drive: jax.Array,
                   c: jax.Array) -> jax.Array:
    """decay, drive: (B, S, D, N); c: (B, S, N) -> y: (B, S, D)."""
    b, s, d, n = decay.shape
    f32 = jnp.float32

    def step(h, xs):
        a_t, b_t, c_t = xs
        h = a_t * h + b_t                           # (B, D, N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (decay.astype(f32).transpose(1, 0, 2, 3),
          drive.astype(f32).transpose(1, 0, 2, 3),
          c.astype(f32).transpose(1, 0, 2))
    h0 = jnp.zeros((b, d, n), f32)
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(decay.dtype)
