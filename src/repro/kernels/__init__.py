# Pallas TPU kernels for the framework's compute hot-spots.  Each package:
#   kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
#   ops.py     jit'd public wrapper (layout/padding handling)
#   ref.py     pure-jnp oracle defining the semantics (tests assert_allclose)
# Kernels are validated with interpret=True on CPU; the dry-run lowers the
# pure-jnp model path since the CPU backend cannot lower TPU Pallas
# (DESIGN.md §6).
