"""Flash attention (training/prefill) as a Pallas TPU kernel.

Online-softmax tiling: grid (batch, q_head, q_blocks, kv_blocks) with the KV
block as the innermost (sequential on TPU) axis; running (m, l, acc) live in
VMEM scratch across KV steps.  Supports GQA (kv-head indexed q_head//group),
causal + sliding-window masks and gemma-style logit softcap.  Block sizes
default to MXU-aligned 128x128 tiles; VMEM working set per step is
q(Bq x D) + k,v(Bk x D) + acc(Bq x D) + scores(Bq x Bk) — ~1.3 MB at
Bq=Bk=128, D=128 in f32, far under the ~128 MB v5e VMEM budget, leaving the
pipeliner headroom to double-buffer the K/V streams.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            sm_scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, nk: int, kv_len: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qi = pl.program_id(2)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < kv_len                  # drop padded keys
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, kv_len: int = 0,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Kh, Sk, D) with H % Kh == 0.
    Returns (B, H, Sq, D).  kv_len masks padded keys (0 = all valid)."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_kernel, sm_scale=sm_scale, causal=causal,
                               window=window, softcap=softcap, bq=bq, bk=bk,
                               nk=nk, kv_len=kv_len or sk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, q_, k_, g=group: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, q_, k_, g=group: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
