"""Jit'd public wrapper: (B, S, H, D)-layout flash attention with padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        window: int = 0, softcap: float = 0.0, block_q: int = 128,
        block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, Kh, D) -> (B, S, H, D).

    Pads S up to the block size, transposes to the kernel's (B, H, S, D)
    layout and back.  Padding keys are masked out by causality (they sit
    after every real query) plus an explicit tail mask for the non-causal
    case is unnecessary here because padded queries are dropped on return.
    """
    b, s, h, d = q.shape
    bq = min(block_q, max(8, 1 << (s - 1).bit_length()))
    pad = (-s) % bq
    if pad:
        zq = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(x, zq) for x in (q, k, v))
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          softcap=softcap, block_q=bq, block_k=block_k,
                          kv_len=s, interpret=interpret)
    return out.transpose(0, 2, 1, 3)[:, :s]
