"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Kh, Sk, D). Exact softmax attention."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    group = h // kh
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
