"""Jit'd wrapper for the chunkwise mLSTM kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm(q: jax.Array, k: jax.Array, v: jax.Array, i: jax.Array,
          f: jax.Array, *, chunk: int = 128,
          interpret: bool = True) -> jax.Array:
    """(B, S, H, D) layout wrapper; gates (B, S, H)."""
    out = mlstm_chunk(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), i.transpose(0, 2, 1),
                      f.transpose(0, 2, 1), chunk=chunk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
