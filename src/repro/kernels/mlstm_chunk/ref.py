"""Fully-recurrent (per-timestep) oracle for the chunkwise mLSTM kernel —
the stabilized mLSTM cell exactly as in the xLSTM paper."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlstm_recurrent_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        i: jax.Array, f: jax.Array) -> jax.Array:
    """q,k,v: (B, H, S, D); i,f: (B, H, S). Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    f32 = jnp.float32
    q = q.astype(f32)
    k = k.astype(f32) / math.sqrt(d)
    v = v.astype(f32)
    lf = jax.nn.log_sigmoid(f.astype(f32))
    ig = i.astype(f32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, lft = xs
        m_new = jnp.maximum(lft + m, it)
        fg = jnp.exp(lft + m - m_new)[..., None]
        iw = jnp.exp(it - m_new)[..., None]
        C = fg[..., None] * C + iw[..., None] * \
            jnp.einsum("bhd,bhe->bhde", kt, vt)
        n = fg * n + iw * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        h_out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        return (C, n, m_new), h_out

    carry0 = (jnp.zeros((b, h, d, d), f32), jnp.zeros((b, h, d), f32),
              jnp.full((b, h), -1e30, f32))
    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), ig.transpose(2, 0, 1),
          lf.transpose(2, 0, 1))
    _, hs = jax.lax.scan(step, carry0, xs)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype)
