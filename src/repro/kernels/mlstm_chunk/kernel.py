"""Chunkwise mLSTM (xLSTM matrix-memory) as a Pallas TPU kernel.

Grid (batch, head, chunks); the chunk axis is innermost/sequential, carrying
the inter-chunk state (C: (D, D), n: (D,), m: scalar) in VMEM scratch —
exactly the recurrence of `repro.models.ssm.mlstm_chunk_scan`, with the
within-chunk part computed as a decayed-score attention matrix on the MXU.
VMEM per step at D=256, L=128: C 256 KB + qkv 3*128*256*4 = ~640 KB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
            c_ref, n_ref, m_ref, *, L: int, d: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    q = q_ref[0, 0].astype(jnp.float32)            # (L, d)
    k = k_ref[0, 0].astype(jnp.float32) / math.sqrt(d)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = i_ref[0, 0].astype(jnp.float32)           # (L,) log input gate
    lf = jax.nn.log_sigmoid(f_ref[0, 0].astype(jnp.float32))

    F = jnp.cumsum(lf)                              # (L,) inclusive
    # intra log-weights D[t,s] = F_t - F_s + i_s for s <= t
    Dm = F[:, None] - F[None, :] + ig[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    Dm = jnp.where(tri, Dm, -jnp.inf)
    m_prev = m_ref[...]
    m_intra = jnp.max(Dm, axis=1)
    m_inter = F + m_prev
    m_t = jnp.maximum(m_intra, m_inter)             # (L,)

    w_intra = jnp.exp(Dm - m_t[:, None])
    w_inter = jnp.exp(m_inter - m_t)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (L, L)
    num = jax.lax.dot(w_intra * scores, v) + \
        w_inter[:, None] * jax.lax.dot(q, c_ref[...])
    den = jnp.sum(w_intra * scores, axis=1) + \
        w_inter * (q @ n_ref[...])
    h = num / jnp.maximum(jnp.abs(den), 1.0)[:, None]
    o_ref[0, 0] = h.astype(o_ref.dtype)

    # end-of-chunk state
    Ftot = F[L - 1]
    m_end = m_t[L - 1]
    g_old = jnp.exp(Ftot + m_prev - m_end)
    w_end = jnp.exp(Ftot - F + ig - m_end)          # (L,)
    c_ref[...] = g_old * c_ref[...] + \
        jax.lax.dot_general(k * w_end[:, None], v, (((0,), (0,)), ((), ())))
    n_ref[...] = g_old * n_ref[...] + jnp.sum(k * w_end[:, None], axis=0)
    m_ref[...] = m_end


def mlstm_chunk(q: jax.Array, k: jax.Array, v: jax.Array, i: jax.Array,
                f: jax.Array, *, chunk: int = 128,
                interpret: bool = True) -> jax.Array:
    """q,k,v: (B, H, S, D); i,f: (B, H, S) pre-activation gates.
    Returns h: (B, H, S, D).  NOTE: k is scaled by 1/sqrt(D) inside."""
    b, h, s, d = q.shape
    L = min(chunk, s)
    assert s % L == 0
    nc = s // L
    kernel = functools.partial(_kernel, L=L, d=d)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, L, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, L, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, L), lambda b_, h_, c_: (b_, h_, c_)),
            pl.BlockSpec((1, 1, L), lambda b_, h_, c_: (b_, h_, c_)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, i, f)
