"""Discrete-event execution of iterative dataflow jobs on a multi-tenant
cluster (paper §V-A/B): Ernest-form stage runtimes modulated by background
interference (AR(1)), data-locality noise, rescale overheads and the paper's
failure injector (one executor kill at a random second per 90 s window while
more than 4 executors remain; Spark restores the executor after a delay).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.dataflow.workloads import JobSpec, StageSpec

FAILURE_WINDOW = 90.0
RESTART_DELAY = 25.0          # seconds until the replacement executor joins
RETRY_PENALTY = 18.0          # lost-task recompute cost charged to the stage
RESCALE_BASE = 4.0            # fixed rescale overhead (renegotiation)
RESCALE_PER_EXEC = 0.35       # per-executor-delta overhead (state movement)


@dataclass
class StageRecord:
    name: str
    start: float
    runtime: float
    start_scaleout: float      # a_i
    end_scaleout: float        # z_i
    time_fraction: float       # r_i: fraction spent in end scale-out
    overhead: float            # rescale overhead attributed to this stage
    metrics: np.ndarray        # the 5 paper metrics
    failures: int = 0


@dataclass
class ComponentRecord:
    comp_idx: int
    stages: List[StageRecord]

    @property
    def runtime(self) -> float:
        return sum(s.runtime for s in self.stages)

    @property
    def scaleout(self) -> float:
        return self.stages[-1].end_scaleout


@dataclass
class RunRecord:
    job: str
    target_runtime: float
    components: List[ComponentRecord] = field(default_factory=list)
    rescales: List[Tuple[int, int, int]] = field(default_factory=list)
    failures: List[float] = field(default_factory=list)

    @property
    def runtime(self) -> float:
        return sum(c.runtime for c in self.components)

    @property
    def violation(self) -> float:
        return max(0.0, self.runtime - self.target_runtime)


class ClusterSim:
    """Shared-cluster environment; one instance per experiment sequence so
    interference is a persistent AR(1) process across runs."""

    def __init__(self, seed: int = 0, interference_scale: float = 0.12):
        self.rng = np.random.RandomState(seed)
        self._interf = 0.0
        self.interference_scale = interference_scale

    def interference(self) -> float:
        """AR(1) background load in [0, ~0.4]: multi-tenant competition."""
        self._interf = 0.85 * self._interf + 0.15 * abs(
            self.rng.randn()) * self.interference_scale * 2
        return float(np.clip(self._interf, 0.0, 0.45))

    def locality(self) -> float:
        """Data-locality slowdown factor >= 1 (tasks not on data nodes)."""
        return 1.0 + max(0.0, self.rng.randn() * 0.04 + 0.02)

    # ----------------------------------------------------------------- stage
    def _stage_metrics(self, spec: StageSpec, s: float, interf: float,
                       failed: bool) -> np.ndarray:
        """[cpu_util, shuffle_rw, data_io, gc_frac, spill_ratio] (§IV-B)."""
        mem_pressure = np.clip(12.0 / s, 0.0, 2.5)       # fewer executors ->
        gc = 0.04 + 0.05 * mem_pressure + (0.05 if failed else 0.0)
        spill = max(0.0, mem_pressure - 1.4) * 0.3
        cpu = np.clip(spec.cpu * (1 - interf) + self.rng.randn() * 0.02, 0, 1)
        shuffle = spec.shuffle * (1 + 0.25 * np.log2(max(s, 2)) / 5)
        io = spec.io * (1 + (0.3 if failed else 0.0))
        return np.array([cpu, shuffle, io, gc, spill], np.float32)

    def run_stage(self, spec: StageSpec, *, start_scaleout: int,
                  end_scaleout: int, clock: float, rescale_overhead: float,
                  inject_failures: bool, failures_log: List[float]
                  ) -> StageRecord:
        a, z = float(start_scaleout), float(end_scaleout)
        interf = self.interference()
        loc = self.locality()
        s_eff = z
        failed = False
        base = spec.runtime(s_eff)
        t = base * (1 + interf) * loc + self.rng.randn() * 0.15 * np.sqrt(base)
        t = float(max(t, 0.2))
        # failure injector: one kill per 90s window at a random second, only
        # while > 4 executors are alive (paper §V-B.4)
        if inject_failures and z > 4:
            n_windows = int((clock + t) // FAILURE_WINDOW) - int(
                clock // FAILURE_WINDOW)
            for w in range(n_windows):
                when = (int(clock // FAILURE_WINDOW) + 1 + w) * FAILURE_WINDOW \
                    - self.rng.uniform(0, FAILURE_WINDOW)
                if clock <= when <= clock + t:
                    failed = True
                    failures_log.append(when)
                    # degraded scale until restart + retry recompute
                    frac = min(RESTART_DELAY, t) / max(t, 1e-6)
                    slow = spec.runtime(max(z - 1, 1)) / max(base, 1e-6)
                    t = t * (1 - frac) + t * frac * slow + RETRY_PENALTY
        r_frac = 1.0 if a == z else 0.8      # fraction in end scale-out
        rec = StageRecord(
            name=spec.name, start=clock, runtime=t + rescale_overhead,
            start_scaleout=a, end_scaleout=z, time_fraction=r_frac,
            overhead=rescale_overhead,
            metrics=self._stage_metrics(spec, z, interf, failed),
            failures=int(failed))
        return rec

    # -------------------------------------------------------------- component
    def run_component(self, job: JobSpec, comp_idx: int, *, clock: float,
                      start_scaleout: int, end_scaleout: int,
                      inject_failures: bool, failures_log: List[float]
                      ) -> ComponentRecord:
        overhead_total = 0.0
        if start_scaleout != end_scaleout:
            overhead_total = RESCALE_BASE + RESCALE_PER_EXEC * abs(
                end_scaleout - start_scaleout)
        stages = []
        specs = job.stages(comp_idx)
        for i, spec in enumerate(specs):
            ov = overhead_total if i == 0 else 0.0
            a = start_scaleout if i == 0 else end_scaleout
            rec = self.run_stage(spec, start_scaleout=a,
                                 end_scaleout=end_scaleout, clock=clock,
                                 rescale_overhead=ov,
                                 inject_failures=inject_failures,
                                 failures_log=failures_log)
            stages.append(rec)
            clock += rec.runtime
        return ComponentRecord(comp_idx, stages)


def rescale_overhead(a: int, z: int) -> float:
    return 0.0 if a == z else RESCALE_BASE + RESCALE_PER_EXEC * abs(z - a)
