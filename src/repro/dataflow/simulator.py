"""Discrete-event execution of iterative dataflow jobs on a multi-tenant
cluster (paper §V-A/B): Ernest-form stage runtimes modulated by background
interference (AR(1)), data-locality noise, rescale overheads and the paper's
failure injector (one executor kill at a seeded random second per 90 s
window while more than 4 executors remain; Spark restores the executor after
a delay).

This module is the *numpy reference engine* of the scenario subsystem
(``repro.sim``): every stage is computed with IEEE-exact float32 scalar ops
reading precomputed lookup tables (``repro.sim.tables``), in an op order the
vectorized jnp engine (``repro.sim.engine``) replicates bit-for-bit at
batch=1.  Scenario disturbances (stragglers, bursts, preemption, skew — see
``repro.sim.scenarios``) come from seeded tables both engines share.

Shared float32 stage recipe (canonical; the jnp engine mirrors it exactly,
guarding every product that feeds an add against FMA contraction):

    w0     = floor(clock / 90);  window-indexed tables use min(w0, W_MAX-1)
    innov  = |n0| * (2*interference_scale * burst[w0])
    interf = clip(0.85*interf + 0.15*innov, 0, 0.45)          # AR(1)
    loc    = 1 + max(0, n1*0.04 + 0.02)                       # data locality
    z_eff  = max(z - preempt[w0], 1)                          # spot loss
    t      = rt[z_eff]*(1+interf)*loc + n2*(0.15*sq[z_eff])
    t      = max(t, 0.2) * straggler[stage_idx]
    for each window w covering [clock, clock+t):              # z > 4 only
        if kill_time[run, w] in [clock, clock+t):             # per-window
            frac = min(25, t)/max(t, 1e-6); t = t*(1-frac) +
                   (t*frac)*slow[z_eff] + 18                  # retry cost
    runtime = t + rescale_overhead
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.dataflow.workloads import JobSpec, StageSpec
from repro.sim.scenarios import BASELINE, Scenario
from repro.sim.tables import (F32, GLOBAL, MAX_FAIL_WINDOWS, N_NOISE, R_MAX,
                              T_STRAGGLER, W_MAX, overhead_f32, stage_tables)

FAILURE_WINDOW = 90.0
RESTART_DELAY = 25.0          # seconds until the replacement executor joins
RETRY_PENALTY = 18.0          # lost-task recompute cost charged to the stage
RESCALE_BASE = 4.0            # fixed rescale overhead (renegotiation)
RESCALE_PER_EXEC = 0.35       # per-executor-delta overhead (state movement)

_W90 = F32(FAILURE_WINDOW)


@dataclass
class StageRecord:
    name: str
    start: float
    runtime: float
    start_scaleout: float      # a_i
    end_scaleout: float        # z_i
    time_fraction: float       # r_i: fraction spent in end scale-out
    overhead: float            # rescale overhead attributed to this stage
    metrics: np.ndarray        # the 5 paper metrics
    failures: int = 0


@dataclass
class ComponentRecord:
    comp_idx: int
    stages: List[StageRecord]

    @property
    def runtime(self) -> float:
        return sum(s.runtime for s in self.stages)

    @property
    def scaleout(self) -> float:
        return self.stages[-1].end_scaleout


@dataclass
class RunRecord:
    job: str
    target_runtime: float
    components: List[ComponentRecord] = field(default_factory=list)
    rescales: List[Tuple[int, int, int]] = field(default_factory=list)
    failures: List[float] = field(default_factory=list)

    @property
    def runtime(self) -> float:
        return sum(c.runtime for c in self.components)

    @property
    def violation(self) -> float:
        return max(0.0, self.runtime - self.target_runtime)


class ClusterSim:
    """Shared-cluster environment; one instance per experiment sequence so
    interference is a persistent AR(1) process across runs.

    Noise discipline: each stage consumes exactly ``N_NOISE`` sequential
    ``randn`` draws from ``self.rng`` (interference innovation, locality,
    runtime noise, cpu-metric noise) — a path-independent count, so the
    vectorized engine can mirror the stream by drawing a run's block of
    ``randn(T, N_NOISE)`` upfront from an identically-seeded RandomState.
    """

    def __init__(self, seed: int = 0, interference_scale: float = 0.12,
                 scenario: Optional[Scenario] = None):
        self.rng = np.random.RandomState(seed)
        self.seed = seed
        self.scenario = scenario or BASELINE
        self.interference_scale = interference_scale
        self._iscale2 = F32(interference_scale * 2.0)
        self._win = self.scenario.window_tables(seed)
        self._interf = F32(0.0)
        self.run_idx = 0              # kill-table row of the current run
        self._runs_started = 0
        self.stage_idx = 0            # global stage counter (straggler stream)
        self._spec_tab: Dict[Tuple[StageSpec, int], Dict] = {}

    def begin_run(self) -> int:
        """Mark the start of a run: selects this run's seeded kill-second
        row.  The vectorized engine calls the same hook in lockstep."""
        self.run_idx = self._runs_started
        self._runs_started += 1
        return self.run_idx

    # ----------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict:
        """Every mutable field a trace-identical resume needs.  The seeded
        window tables and the stage-spec cache are deterministic in
        (scenario, seed) and rebuilt on construction, so they stay out."""
        return {
            "rng": self.rng.get_state(),
            "interf": F32(self._interf),
            "run_idx": int(self.run_idx),
            "runs_started": int(self._runs_started),
            "stage_idx": int(self.stage_idx),
        }

    def load_state_dict(self, state: Dict) -> None:
        self.rng.set_state(state["rng"])
        self._interf = F32(state["interf"])
        self.run_idx = int(state["run_idx"])
        self._runs_started = int(state["runs_started"])
        self.stage_idx = int(state["stage_idx"])

    def _tables(self, spec: StageSpec, comp_idx: int) -> Dict:
        key = (spec, comp_idx)
        tab = self._spec_tab.get(key)
        if tab is None:
            growth = float(self.scenario.skew_growth) ** comp_idx
            tab = stage_tables(spec, growth)
            self._spec_tab[key] = tab
        return tab

    # ----------------------------------------------------------------- stage
    def run_stage(self, spec: StageSpec, *, start_scaleout: int,
                  end_scaleout: int, clock: float, rescale_overhead: float,
                  inject_failures: bool, failures_log: List[float],
                  comp_idx: int = 0) -> StageRecord:
        tab = self._tables(spec, comp_idx)
        a, z = int(start_scaleout), int(end_scaleout)
        clock = F32(clock)
        n = self.rng.randn(N_NOISE).astype(F32)
        w0 = int(np.floor(clock / _W90))
        wi0 = min(max(w0, 0), W_MAX - 1)
        # AR(1) interference, burst-modulated innovation
        innov = np.abs(n[0]) * (self._iscale2 * self._win["burst"][wi0])
        interf = F32(0.85) * self._interf + F32(0.15) * innov
        self._interf = interf = min(max(interf, F32(0.0)), F32(0.45))
        loc = F32(1.0) + max(F32(0.0), n[1] * F32(0.04) + F32(0.02))
        z_eff = max(z - int(self._win["preempt"][wi0]), 1)
        base = tab["rt"][z_eff]
        t = base * (F32(1.0) + interf) * loc + n[2] * (F32(0.15) *
                                                       tab["sq"][z_eff])
        t = max(t, F32(0.2))
        t = t * self._win["straggler"][self.stage_idx % T_STRAGGLER]
        t0 = t
        failed = 0
        # failure injector (paper §V-B.4): each 90 s window has ONE seeded
        # kill second (per window AND per run — the old engine re-drew it
        # per stage, so overlapping stages disagreed about the kill time);
        # the kill fires in whichever stage covers that second, only while
        # > 4 executors are allocated.
        if inject_failures and z > 4:
            w_hi = min(int(np.floor((clock + t0) / _W90)),
                       w0 + MAX_FAIL_WINDOWS - 1)
            kill_row = self._win["kill_time"][self.run_idx % R_MAX]
            for w in range(w0, w_hi + 1):
                when = kill_row[min(max(w, 0), W_MAX - 1)]
                if (when >= clock) and (when < clock + t0):
                    failed += 1
                    failures_log.append(float(when))
                    # degraded scale until restart + retry recompute
                    frac = min(F32(RESTART_DELAY), t) / max(t, F32(1e-6))
                    t = t * (F32(1.0) - frac) + \
                        (t * frac) * tab["slow"][z_eff] + F32(RETRY_PENALTY)
        runtime = t + F32(rescale_overhead)
        r_frac = F32(1.0) if a == z else F32(0.8)
        rec = StageRecord(
            name=spec.name, start=clock, runtime=runtime,
            start_scaleout=float(a), end_scaleout=float(z),
            time_fraction=float(r_frac), overhead=float(rescale_overhead),
            metrics=self._stage_metrics(tab, z_eff, interf, failed, n[3]),
            failures=failed)
        self.stage_idx += 1
        return rec

    def _stage_metrics(self, tab: Dict, z_eff: int, interf: F32,
                       failed: int, n3: F32) -> np.ndarray:
        """[cpu_util, shuffle_rw, data_io, gc_frac, spill_ratio] (§IV-B)."""
        mem = GLOBAL["mem"][z_eff]                 # fewer executors -> pressure
        gc = F32(0.04) + F32(0.05) * mem
        if failed:
            gc = gc + F32(0.05)
        spill = max(F32(0.0), mem - F32(1.4)) * F32(0.3)
        cpu = tab["cpu0"] * (F32(1.0) - interf) + n3 * F32(0.02)
        cpu = min(max(cpu, F32(0.0)), F32(1.0))
        shuffle = tab["shuffle0"] * GLOBAL["shuf"][z_eff]
        io = tab["io0"] * (F32(1.3) if failed else F32(1.0))
        return np.array([cpu, shuffle, io, gc, spill], F32)

    # -------------------------------------------------------------- component
    def run_component(self, job: JobSpec, comp_idx: int, *, clock: float,
                      start_scaleout: int, end_scaleout: int,
                      inject_failures: bool, failures_log: List[float]
                      ) -> ComponentRecord:
        overhead_total = overhead_f32(start_scaleout, end_scaleout)
        clock = F32(clock)
        stages = []
        specs = job.stages(comp_idx)
        for i, spec in enumerate(specs):
            ov = overhead_total if i == 0 else F32(0.0)
            a = start_scaleout if i == 0 else end_scaleout
            rec = self.run_stage(spec, start_scaleout=a,
                                 end_scaleout=end_scaleout, clock=clock,
                                 rescale_overhead=ov,
                                 inject_failures=inject_failures,
                                 failures_log=failures_log,
                                 comp_idx=comp_idx)
            stages.append(rec)
            clock = rec.start + rec.runtime
        return ComponentRecord(comp_idx, stages)


def rescale_overhead(a: int, z: int) -> float:
    return 0.0 if a == z else RESCALE_BASE + RESCALE_PER_EXEC * abs(z - a)
