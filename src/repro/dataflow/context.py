"""Job execution-context encoding: descriptive properties -> c = u ‖ v ‖ w.

u: always-available properties (job signature, dataset, hardware),
v: not-uniformly-recorded properties (software versions; randomly missing),
w: properties unique to the task set (stage name, #tasks, attempt id).
Each property runs through the hasher/binarizer (eq.1-2), then the trained
auto-encoder; group means give three 8-dim vectors (paper §III-D).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.autoencoder import embed_properties, train_autoencoder
from repro.core.encoding import encode_properties
from repro.dataflow.workloads import JobSpec

HARDWARE = ["intel xeon 3.3 ghz", 8, 16, "1gb switch"]
SOFTWARE = ["spark 3.1", "kubernetes 1.18.10", "python 3.8.0",
            "hadoop 2.8.3", "scala 2.12.11"]
EXECUTOR = [6, 10240]      # cores, memory MB (Table I)


class ContextEncoder:
    """Fits the auto-encoder once on the property pool, then embeds."""

    def __init__(self, jobs: Sequence[JobSpec], seed: int = 0):
        self.rng = np.random.RandomState(seed)
        pool: List = []
        for job in jobs:
            pool += self._u_props(job) + SOFTWARE
            for c in range(job.n_components):
                for st in job.stages(c):
                    pool += [st.name, 64, 0]
        vecs = encode_properties(pool)
        self.ae_params, self.ae_loss = train_autoencoder(vecs, steps=400)
        self._cache: Dict[str, np.ndarray] = {}

    def _u_props(self, job: JobSpec) -> List:
        return ([job.name, job.params, job.dataset.name,
                 int(job.dataset.size_gb)] + HARDWARE + EXECUTOR)

    def _embed(self, props: List) -> np.ndarray:
        key = repr(props)
        if key not in self._cache:
            vecs = encode_properties(props)
            emb = embed_properties(self.ae_params, vecs)
            self._cache[key] = emb.mean(axis=0).astype(np.float32)
        return self._cache[key]

    def node_context(self, job: JobSpec, stage_name: str, n_tasks: int,
                     attempt: int = 0, drop_versions: bool = True
                     ) -> np.ndarray:
        u = self._embed(self._u_props(job))
        sw = [s for s in SOFTWARE
              if not (drop_versions and self.rng.rand() < 0.2)]
        v = self._embed(sw) if sw else np.zeros(8, np.float32)
        w = self._embed([stage_name, int(n_tasks), int(attempt)])
        return np.concatenate([u, v, w]).astype(np.float32)
