"""The paper's experiment protocol (§V-B): profiling runs, adaptive runs with
dynamic scaling (Enel vs Ellis), failure phases, CVC/CVS metrics.

Per job: 10 profiling runs (no scaling) -> initial model fit -> adaptive runs
where the scaler is consulted at every component boundary.  Enel retrains
from scratch every 5th run and fine-tunes otherwise; Ellis refits its
per-component model ensemble after every run.

The execution loop is a generator that YIELDS two kinds of requests and
receives their results:

* :class:`~repro.sim.engine.SimStepRequest` — the next component's
  simulated execution, answered by a sim backend: the per-job numpy event
  loop (:class:`~repro.sim.engine.NumpySimBackend`, ``engine="numpy"``) or
  the vectorized fleet engine
  (:class:`~repro.sim.engine.BatchedClusterSim`, ``engine="batched"``,
  bit-identical at batch=1), which a fleet campaign steps for ALL
  concurrent jobs in one device dispatch;
* :class:`~repro.core.service.DecisionRequest` — the pending rescaling
  decision, answered by a :class:`~repro.core.service.DecisionService`
  (shape-bucketed; cross-job batched under a campaign).

Disturbance scenarios (``repro.sim.scenarios``) and dataset-size scaling
(``size_scale``) parameterize the execution context; ``share_models_from``
transplants a trained model into a new context for the paper's
cross-context reuse claim (see ``repro.sim.evaluate``).
"""
from __future__ import annotations

import copy
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.graph import (ComponentGraph, NodeAttrs, build_graph,
                              historical_summary, summary_node)
from repro.core.scaling import EnelScaler
from repro.core.ellis import EllisScaler
from repro.core.service import DecisionRequest, DecisionService
from repro.core.training import EnelTrainer
from repro.dataflow.context import ContextEncoder
from repro.dataflow.simulator import (ClusterSim, ComponentRecord, RunRecord,
                                      rescale_overhead)
from repro.dataflow.workloads import JOBS, SCALEOUT_RANGE, JobSpec, scale_job
from repro.sim.engine import (BatchedClusterSim, NumpySimBackend,
                              SimStepRequest)
from repro.sim.scenarios import BASELINE, Scenario

PROFILING_SCALEOUTS = [4, 8, 11, 14, 18, 21, 25, 28, 32, 36]
HISTORY_WINDOW = 96           # newest graphs kept for scratch retraining


@dataclass
class RunStats:
    run_idx: int
    kind: str                 # profiling | enel | ellis
    runtime: float
    target: float
    violation: float
    predicted: Optional[float] = None
    scaleouts: List[int] = field(default_factory=list)
    n_failures: int = 0
    n_rescales: int = 0
    fit_seconds: float = 0.0
    decide_seconds: float = 0.0
    decide_calls: int = 0
    # sweep-template device-cache traffic during this run (LRU-bounded)
    cache_transfers: int = 0
    cache_skips: int = 0
    cache_evictions: int = 0
    # fault-tolerance counters: decisions answered by the model-free
    # fallback / shed under overload during this run, plus this run's share
    # of service-wide dispatch retries and breaker trips (deltas over the
    # run — service-wide under a fleet campaign, see adaptive_run_gen)
    fallback_decisions: int = 0
    shed_requests: int = 0
    retries: int = 0
    breaker_trips: int = 0

    @property
    def cvc(self) -> int:
        return int(self.violation > 0)

    @property
    def decide_seconds_per_call(self) -> float:
        return self.decide_seconds / self.decide_calls if self.decide_calls \
            else 0.0


def _component_nodes(encoder: ContextEncoder, job: JobSpec,
                     comp: ComponentRecord) -> List[NodeAttrs]:
    nodes = []
    for st in comp.stages:
        ctx = encoder.node_context(job, st.name, int(st.end_scaleout * 4),
                                   attempt=st.failures)
        nodes.append(NodeAttrs(
            name=st.name, context=ctx, metrics=st.metrics,
            start_scaleout=st.start_scaleout, end_scaleout=st.end_scaleout,
            time_fraction=st.time_fraction, runtime=st.runtime,
            overhead=st.overhead if st.overhead > 0 else None))
    return nodes


def _future_nodes(encoder: ContextEncoder, job: JobSpec, comp_idx: int,
                  a: float, z: float) -> List[NodeAttrs]:
    nodes = []
    for i, spec in enumerate(job.stages(comp_idx)):
        ctx = encoder.node_context(job, spec.name, int(z * 4))
        nodes.append(NodeAttrs(
            name=spec.name, context=ctx, metrics=None,
            start_scaleout=a if i == 0 else z, end_scaleout=z,
            time_fraction=1.0 if a == z else 0.8))
    return nodes


def frozen_context_tables(encoder: ContextEncoder, job: JobSpec
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic node-context tables for the fused campaign planner.

    Returns ``(ctx (C, S_max, NS, CTX_DIM) f32, n_stages (C,) int32)`` with
    NS spanning the whole scale-out grid (``SCALEOUT_RANGE[0]..[1]``): entry
    ``[c, i, s - lo]`` is component c / stage i's context at scale-out s.
    Built with ``drop_versions=False`` so NO encoder RNG is consumed — the
    fused campaign freezes contexts at plan time (documented deviation from
    the live path's per-observation software-version dropout; ``attempt`` is
    likewise frozen at 0).  The embed cache makes repeat lookups cheap.
    """
    lo, hi = SCALEOUT_RANGE
    grid = np.arange(lo, hi + 1)
    n_comp = job.n_components
    s_max = max(len(job.stages(c)) for c in range(n_comp))
    ctx = np.zeros((n_comp, s_max, len(grid), 24), np.float32)
    n_stages = np.zeros(n_comp, np.int32)
    for c in range(n_comp):
        specs = job.stages(c)
        n_stages[c] = len(specs)
        for i, spec in enumerate(specs):
            for si, s in enumerate(grid):
                ctx[c, i, si] = encoder.node_context(
                    job, spec.name, int(s * 4), drop_versions=False)
    return ctx, n_stages


def _to_graph(nodes: List[NodeAttrs], preds: List[NodeAttrs],
              comp_idx: int) -> ComponentGraph:
    n = len(nodes)
    all_nodes = nodes + preds
    edges = [(i, i + 1) for i in range(n - 1)]
    edges += [(n + j, 0) for j in range(len(preds))]
    return build_graph(all_nodes, edges, component_id=comp_idx)


def drive(gen, service: Optional[DecisionService], backend=None):
    """Run an execution generator to completion, answering each yielded
    :class:`SimStepRequest` with the backend's component record and each
    :class:`DecisionRequest` with the service's decision."""
    try:
        req = next(gen)
        while True:
            if isinstance(req, SimStepRequest):
                req = gen.send(backend.step([req])[0])
            else:
                req = gen.send(service.decide([req])[0])
    except StopIteration as stop:
        return stop.value


class JobExperiment:
    """Shared environment for one job: simulator, encoder, both scalers.

    ``engine`` selects the sim backend ("numpy": per-job reference event
    loop; "batched": vectorized engine — bit-identical, and batched across
    jobs when a shared ``backend`` is passed, e.g. by a fleet campaign).
    ``scenario`` injects seeded disturbances; ``size_scale`` scales the
    dataset (cross-context axis); ``share_models_from`` reuses another
    experiment's trained model/encoder/scalers instead of fresh ones
    (transfer deployment — the source experiment should be done running).
    """

    def __init__(self, job_key: str, seed: int = 0,
                 candidate_stride: int = 2,
                 service: Optional[DecisionService] = None,
                 engine: str = "numpy",
                 scenario: Optional[Scenario] = None,
                 backend=None, size_scale: float = 1.0,
                 share_models_from: Optional["JobExperiment"] = None):
        job = JOBS[job_key]
        if size_scale != 1.0:
            job = scale_job(job, size_scale)
        self.job = job
        self.job_key = job_key
        self.seed = seed
        self.scenario = scenario or BASELINE
        self.engine = engine
        self.sim = ClusterSim(seed=seed, scenario=self.scenario)
        if backend is not None:
            self.backend = backend
        elif engine == "batched":
            self.backend = BatchedClusterSim()
        elif engine == "numpy":
            self.backend = NumpySimBackend()
        else:
            raise ValueError(f"unknown engine {engine!r}")
        if isinstance(self.backend, NumpySimBackend):
            self.sim_slot = self.backend.adopt(self.sim, self.job)
        else:
            self.sim_slot = self.backend.register(self.job, seed,
                                                  self.scenario)
        if share_models_from is not None:
            src = share_models_from
            self.encoder = src.encoder
            self.trainer = src.trainer
            self.enel = src.enel
            self.ellis = src.ellis
        else:
            self.encoder = ContextEncoder([self.job], seed=seed)
            self.trainer = EnelTrainer(seed=seed,
                                       cache_capacity=HISTORY_WINDOW)
            self.enel = EnelScaler(self.trainer, SCALEOUT_RANGE,
                                   candidate_stride=candidate_stride)
            self.ellis = EllisScaler(SCALEOUT_RANGE,
                                     rescale_overhead=rescale_overhead(4, 8),
                                     candidate_stride=candidate_stride)
        self.service = service or DecisionService()
        # decision cadence: every component for short jobs, every 2nd for
        # the 22-component LR/MPC (keeps the campaign tractable on 1 core)
        self.decision_interval = 2 if self.job.n_components > 15 else 1
        self.scale_cap: Optional[int] = None   # multi-tenant capacity cap
        self.best_effort = False     # shed first under service overload
        self.chaos = None            # optional per-experiment fault injector
        self.graph_history: List[ComponentGraph] = []
        self.target: Optional[float] = None
        self.stats: List[RunStats] = []
        self._run_idx = 0

    # ----------------------------------------------------------- checkpoint
    def snapshot_state(self) -> Dict:
        """Everything a trace-identical resume needs: learned state (model
        params, optimizer moments, cache rings, observation histories), the
        sim slot's RNG/clock state and the bookkeeping counters.  Perf-only
        caches (sweep templates, probe masks, memoized stacks) are skipped —
        they repopulate deterministically.  Graph/summary lists hold
        append-only immutable records, so shallow list copies suffice."""
        return {
            "run_idx": int(self._run_idx),
            "target": self.target,
            "scale_cap": self.scale_cap,
            "best_effort": bool(self.best_effort),
            "stats": copy.deepcopy(self.stats),
            "graph_history": list(self.graph_history),
            # node_context consumes the encoder's rng per call (the random
            # version-dropout of the v-group), so replay must re-draw the
            # same stream
            "encoder_rng": self.encoder.rng.get_state(),
            "trainer": self.trainer.snapshot_state(),
            "enel": {
                "hist_summaries": {k: list(v) for k, v in
                                   self.enel.hist_summaries.items()},
                "first_component_history":
                    list(self.enel.first_component_history),
                "fallback_decisions": int(self.enel.fallback_decisions),
                # NOT perf-only: a probe-cache MISS makes build_sweep call
                # the graph builder twice more, consuming encoder rng draws
                # — the hit/miss pattern must replay exactly (entries are
                # immutable tuples, a shallow dict copy suffices)
                "probe_cache": dict(self.enel._probe_cache),
            },
            "ellis_history": {k: list(v) for k, v in
                              self.ellis.history.items()},
            # fitted models are snapshotted, NOT refit on restore: under
            # method="enel" they are deliberately stale relative to the
            # growing history (last fit at profile time), and a refit would
            # diverge the s0 recommendation from the uninterrupted trace
            "ellis_models": copy.deepcopy(self.ellis.models),
            "backend": self.backend.slot_state(self.sim_slot),
        }

    def restore_state(self, state: Dict) -> None:
        """Inverse of :meth:`snapshot_state`; the snapshot itself is left
        pristine (fresh copies are handed out), so one checkpoint can be
        restored any number of times."""
        self._run_idx = int(state["run_idx"])
        self.target = state["target"]
        self.scale_cap = state["scale_cap"]
        self.best_effort = bool(state["best_effort"])
        self.stats = copy.deepcopy(state["stats"])
        self.graph_history = list(state["graph_history"])
        self.encoder.rng.set_state(state["encoder_rng"])
        self.trainer.restore_state(state["trainer"])
        self.enel.hist_summaries = defaultdict(
            list, {k: list(v) for k, v in
                   state["enel"]["hist_summaries"].items()})
        self.enel.first_component_history = \
            list(state["enel"]["first_component_history"])
        self.enel.fallback_decisions = \
            int(state["enel"]["fallback_decisions"])
        self.enel._probe_cache = dict(state["enel"]["probe_cache"])
        self.ellis.history = defaultdict(
            list, {k: list(v) for k, v in state["ellis_history"].items()})
        self.ellis.models = copy.deepcopy(state["ellis_models"])
        self.backend.restore_slot(self.sim_slot, state["backend"])

    # ------------------------------------------------------------ execution
    def _execute_gen(self, *, scaler: Optional[str], inject_failures: bool,
                     initial_s: int):
        """Generator form of one run: yields Enel decision requests, resumes
        with the service's :class:`DecisionResult`, returns the run tuple."""
        job = self.job
        run = RunRecord(job.name, self.target or 0.0)
        self.backend.begin_run(self.sim_slot)
        clock = 0.0
        s_prev = s = initial_s
        scaleouts = [s]
        run_graphs: List[ComponentGraph] = []
        prev_summary: Optional[NodeAttrs] = None
        decide_s = 0.0
        decide_n = 0
        fallback_n = 0
        shed_n = 0
        for k in range(job.n_components):
            step = yield SimStepRequest(
                slot=self.sim_slot, comp_idx=k, start_scaleout=s_prev,
                end_scaleout=s, clock=clock,
                inject_failures=inject_failures)
            comp = step.component
            run.components.append(comp)
            run.failures.extend(step.failures)
            clock = step.clock_end
            nodes = _component_nodes(self.encoder, job, comp)
            preds = [p for p in (prev_summary,) if p is not None]
            if k > 0:
                h = historical_summary(
                    self.enel.hist_summaries.get(k - 1, []), float(s))
                if h is not None:
                    preds.append(h)
            run_graphs.append(_to_graph(nodes, preds, k))
            # record AFTER building this graph (history = previous runs only)
            self.enel.record_component(k, nodes, comp.runtime)
            self.ellis.observe_component(k, comp.scaleout, comp.runtime)
            prev_summary = summary_node(nodes, name=f"P{k}")
            s_prev = s
            # --- dynamic scaling decision at the component boundary
            if scaler and k < job.n_components - 1 and \
                    k % self.decision_interval == 0:
                # decision latency = this job's local work + its amortized
                # share of the service dispatch (result.service_seconds);
                # the suspended yield interval is NOT billed — under fleet
                # interleaving it contains every other job's round
                t0 = time.time()
                if scaler == "enel":
                    # batched candidate sweep: template + deltas, one
                    # service dispatch (shape-bucketed; batched across jobs
                    # when a fleet campaign drives the generator).  NOTE:
                    # under this engine node contexts are built once at the
                    # CURRENT scale-out (the z -> n_tasks context dependence
                    # below is frozen across candidates); only a/z/r and
                    # H-summary attrs vary per candidate.
                    builder = lambda ci, a, z, pr: _to_graph(
                        _future_nodes(self.encoder, job, ci, a, z), pr, ci)
                    req = self.enel.prepare_request(
                        graph_builder=builder, next_comp=k + 1,
                        n_components=job.n_components, elapsed=clock,
                        current_scaleout=s, target_runtime=self.target,
                        current_summary=prev_summary,
                        best_effort=self.best_effort)
                    decide_s += time.time() - t0
                    result = yield req
                    t0 = time.time()
                    fallback_n += int(result.fallback)
                    shed_n += int(result.shed)
                    s_new, _, _ = self.enel.apply_decision(req, result)
                    decide_s += result.service_seconds
                else:
                    s_new, _ = self.ellis.recommend(
                        next_comp=k + 1, n_components=job.n_components,
                        elapsed=clock, current_scaleout=s,
                        target_runtime=self.target)
                decide_s += time.time() - t0
                decide_n += 1
                if s_new != s:
                    run.rescales.append((k + 1, s, s_new))
                    s = s_new
                    scaleouts.append(s)
        return run, run_graphs, scaleouts, decide_s, decide_n, fallback_n, \
            shed_n

    def _execute(self, *, scaler: Optional[str], inject_failures: bool,
                 initial_s: int) -> Tuple[RunRecord, List[ComponentGraph],
                                          List[int], float, int, int, int]:
        return drive(self._execute_gen(scaler=scaler,
                                       inject_failures=inject_failures,
                                       initial_s=initial_s), self.service,
                     self.backend)

    # ------------------------------------------------------------ profiling
    def calibrate_target(self, n_runs: int = 10) -> None:
        """Profiling runs WITHOUT a model fit: sets the runtime target and
        fits Ellis, feeding the observation history.  Used standalone by
        cross-context transfer deployments (the transplanted model must not
        be scratch-retrained just to learn the new context's target)."""
        for i in range(n_runs):
            s = PROFILING_SCALEOUTS[i % len(PROFILING_SCALEOUTS)]
            run, graphs, scaleouts, _, _, _, _ = self._execute(
                scaler=None, inject_failures=False, initial_s=s)
            self.graph_history.extend(graphs)
            self.trainer.extend_history(graphs)
            self._run_idx += 1
            self.stats.append(RunStats(self._run_idx, "profiling",
                                       run.runtime, 0.0, 0.0,
                                       scaleouts=scaleouts))
        runtimes = [st.runtime for st in self.stats if st.kind == "profiling"]
        # target: slightly under the median profiled runtime, so meeting it
        # requires actively choosing good scale-outs (cf. §V-B.3)
        self.target = float(np.median(runtimes) * 0.95)
        for st in self.stats:
            st.target = self.target
            st.violation = max(0.0, st.runtime - self.target)
        self.ellis.refit()

    def profile(self, n_runs: int = 10) -> None:
        self.calibrate_target(n_runs)
        # initial model: scratch-train on the resident ring (profiling graphs
        # were appended run-by-run above — no restack)
        self.trainer.fit_resident(steps=160, from_scratch=True)

    # -------------------------------------------------------------- adaptive
    def adaptive_run(self, method: str, inject_failures: bool) -> RunStats:
        return drive(self.adaptive_run_gen(method, inject_failures),
                     self.service, self.backend)

    def adaptive_run_gen(self, method: str, inject_failures: bool):
        """Generator form of :meth:`adaptive_run` for fleet interleaving."""
        assert self.target is not None, "profile() first"
        job = self.job
        cache = self.enel.template_cache
        cache0 = (cache.transfers, cache.skips, cache.evictions)
        # retry/breaker deltas are service-wide (one envelope serves the
        # whole fleet); per-run rows report the delta observed over the run
        svc0 = (self.service.retries, self.service.breaker_trips)
        # fair initial allocation for both methods (paper §V-B.3): Ellis'
        # per-component models pick the cheapest compliant scale-out
        s0, predicted = self.ellis.recommend(
            next_comp=0, n_components=job.n_components, elapsed=0.0,
            current_scaleout=SCALEOUT_RANGE[0], target_runtime=self.target)
        if self.scale_cap is not None:      # multi-tenant admission headroom
            s0 = max(SCALEOUT_RANGE[0], min(s0, int(self.scale_cap)))
        run, graphs, scaleouts, decide_s, decide_n, fallback_n, shed_n = \
            yield from self._execute_gen(
                scaler=method, inject_failures=inject_failures, initial_s=s0)
        if self.chaos is not None:
            # controller-side fault injection: poisoned observations enter
            # the pipeline HERE, upstream of the cache quarantine guardrail
            graphs = self.chaos.poison_graphs(graphs, self._run_idx)
        self.graph_history.extend(graphs)
        # keep the resident ring in sync for BOTH methods so a later Enel
        # scratch retrain sees the full history window
        self.trainer.extend_history(graphs)
        self._run_idx += 1
        fit_s = 0.0
        if method == "enel":
            t0 = time.time()
            # online fast path: graphs are already device-resident, so the
            # cadence fit reuses the ring buffers (no restack per run)
            self.trainer.observe_run_resident(
                retrain_every=5, steps=160, fine_tune_steps=60)
            fit_s = time.time() - t0
            if self.chaos is not None:
                self.chaos.after_fit(self.trainer, self._run_idx)
        else:
            self.ellis.refit()
        st = RunStats(self._run_idx, method, run.runtime, self.target,
                      run.violation, predicted=predicted,
                      scaleouts=scaleouts, n_failures=len(run.failures),
                      n_rescales=len(run.rescales),
                      fit_seconds=fit_s, decide_seconds=decide_s,
                      decide_calls=decide_n,
                      cache_transfers=cache.transfers - cache0[0],
                      cache_skips=cache.skips - cache0[1],
                      cache_evictions=cache.evictions - cache0[2],
                      fallback_decisions=fallback_n, shed_requests=shed_n,
                      retries=self.service.retries - svc0[0],
                      breaker_trips=self.service.breaker_trips - svc0[1])
        self.stats.append(st)
        if obs.enabled():
            reg = obs.registry()
            labels = {"job": job.name, "kind": method}
            reg.counter("enel_runs_total",
                        "adaptive runs completed").labels(**labels).inc()
            if run.violation > 0:
                reg.counter("enel_run_violations_total",
                            "runs exceeding target").labels(**labels).inc()
            obs.emit("run.end", driver="stepped", job=job.name,
                     run=st.run_idx, kind=method,
                     runtime=round(st.runtime, 6),
                     target=round(st.target, 6),
                     violation=round(st.violation, 6),
                     rescales=st.n_rescales, failures=st.n_failures,
                     fallbacks=st.fallback_decisions,
                     shed=st.shed_requests, retries=st.retries,
                     breaker_trips=st.breaker_trips,
                     fit_seconds=round(st.fit_seconds, 6),
                     decide_seconds=round(st.decide_seconds, 6),
                     decide_calls=st.decide_calls)
        return st


def window_stats(stats: List[RunStats], lo: int, hi: int) -> Dict[str, float]:
    """CVC/CVS aggregates over adaptive runs lo..hi (1-based, inclusive)."""
    sel = [s for s in stats if s.kind != "profiling" and lo <= s.run_idx <= hi]
    if not sel:
        return {"cvc_mean": float("nan"), "cvc_median": float("nan"),
                "cvs_mean": float("nan"), "cvs_median": float("nan")}
    cvc = np.array([s.cvc for s in sel], float)
    cvs = np.array([s.violation / 60.0 for s in sel], float)   # minutes
    return {"cvc_mean": float(cvc.mean()), "cvc_median": float(np.median(cvc)),
            "cvs_mean": float(cvs.mean()), "cvs_median": float(np.median(cvs)),
            "n": len(sel)}
