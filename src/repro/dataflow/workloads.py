"""The paper's benchmark jobs (Table II) and synthetic datasets (§V-B.2).

Jobs are iterative Spark-MLlib analogues expressed as sequences of component
stage-DAGs with Ernest-form ground-truth runtimes; dataset generators build
the actual synthetic data (Multiclass, Vandermonde, Points) and the derived
statistics (rows, features, bytes) parameterize the stage cost model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np


# ------------------------------------------------------------------ datasets
def make_multiclass(n: int = 4096, n_features: int = 200, n_classes: int = 3,
                    seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Classification dataset, 3 classes x 200 features (scikit-style)."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(n_classes, n_features) * 2.0
    y = rng.randint(0, n_classes, n)
    x = centers[y] + rng.randn(n, n_features)
    return x.astype(np.float32), y.astype(np.int32)


def make_vandermonde(n: int = 4096, degree: int = 18, noise: float = 0.1,
                     seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Regression data: Vandermonde matrix of a degree-18 polynomial + noise."""
    rng = np.random.RandomState(seed)
    t = rng.uniform(-1, 1, n)
    x = np.vander(t, degree + 1, increasing=True)            # powers 0..18
    coef = rng.randn(degree + 1)
    y = x @ coef + rng.randn(n) * noise
    return x.astype(np.float32), y.astype(np.float32)


def make_points(n: int = 4096, n_clusters: int = 8, seed: int = 0
                ) -> np.ndarray:
    """2-D GMM points: 8 random centers, equal variances."""
    rng = np.random.RandomState(seed)
    centers = rng.uniform(-10, 10, (n_clusters, 2))
    assign = rng.randint(0, n_clusters, n)
    return (centers[assign] + rng.randn(n, 2)).astype(np.float32)


@dataclass(frozen=True)
class Dataset:
    name: str
    size_gb: float
    n_features: int
    generator: str


DATASETS = {
    "multiclass": Dataset("Multiclass", 27.0, 200, "make_multiclass"),
    "vandermonde": Dataset("Vandermonde", 35.0, 19, "make_vandermonde"),
    "points": Dataset("Points", 48.0, 2, "make_points"),
}


# ------------------------------------------------------------------- stages
@dataclass(frozen=True)
class StageSpec:
    """Ground-truth runtime: t(s) = serial + parallel/s + comm*log2(s) + lin*s,
    modulated by interference / locality / failures in the simulator."""
    name: str
    serial: float          # fixed seconds
    parallel: float        # perfectly-parallel seconds (at s=1)
    comm: float            # log-term (aggregation trees)
    lin: float = 0.0       # per-executor overhead (broadcast etc.)
    cpu: float = 0.7       # nominal CPU utilisation metric
    shuffle: float = 0.1   # nominal shuffle r/w metric
    io: float = 0.1        # nominal data I/O metric

    def runtime(self, s: float) -> float:
        return (self.serial + self.parallel / s +
                self.comm * np.log2(max(s, 2)) + self.lin * s)


@dataclass(frozen=True)
class JobSpec:
    name: str
    dataset: Dataset
    iterations: int
    params: str                       # textual job parameters (hashed context)
    prep: Tuple[StageSpec, ...]       # component 0
    iter_stages: Tuple[StageSpec, ...]  # components 1..iterations (chain DAG)
    final: Tuple[StageSpec, ...]      # last component

    @property
    def n_components(self) -> int:
        return self.iterations + 2

    def stages(self, comp_idx: int) -> Tuple[StageSpec, ...]:
        if comp_idx == 0:
            return self.prep
        if comp_idx == self.n_components - 1:
            return self.final
        return self.iter_stages

    def base_runtime(self, s: float) -> float:
        return sum(st.runtime(s) for c in range(self.n_components)
                   for st in self.stages(c))


def _scale(ds: Dataset, per_gb: float) -> float:
    return per_gb * ds.size_gb


def build_jobs() -> Dict[str, JobSpec]:
    mc, vm, pt = DATASETS["multiclass"], DATASETS["vandermonde"], DATASETS["points"]
    jobs = {}
    jobs["lr"] = JobSpec(
        name="LR", dataset=mc, iterations=20, params="20 iterations",
        prep=(StageSpec("read-cache", 4.0, _scale(mc, 14.0), 0.4, io=0.9, cpu=0.3),
              StageSpec("count", 1.0, _scale(mc, 1.0), 0.3, io=0.3, cpu=0.2)),
        iter_stages=(StageSpec("broadcast-weights", 0.8, 0.0, 0.35, 0.04,
                               cpu=0.1, shuffle=0.3),
                     StageSpec("map-gradient", 1.0, _scale(mc, 4.2), 0.0,
                               cpu=0.9, io=0.15),
                     StageSpec("tree-aggregate", 0.6, _scale(mc, 0.3), 0.8,
                               cpu=0.3, shuffle=0.8)),
        final=(StageSpec("model-save", 2.0, 2.0, 0.2, io=0.6, cpu=0.2),))
    jobs["mpc"] = JobSpec(
        name="MPC", dataset=mc, iterations=20,
        params="20 iterations, 4 layers with 200-100-50-3 perceptrons",
        prep=(StageSpec("read-cache", 4.0, _scale(mc, 14.0), 0.4, io=0.9, cpu=0.3),
              StageSpec("init-weights", 1.5, 1.0, 0.2, cpu=0.2)),
        iter_stages=(StageSpec("broadcast-weights", 1.0, 0.0, 0.4, 0.06,
                               cpu=0.1, shuffle=0.35),
                     StageSpec("fwd-bwd", 1.2, _scale(mc, 10.5), 0.0,
                               cpu=0.95, io=0.1),
                     StageSpec("tree-aggregate", 0.8, _scale(mc, 0.5), 1.0,
                               cpu=0.3, shuffle=0.85)),
        final=(StageSpec("model-save", 2.0, 2.0, 0.2, io=0.6, cpu=0.2),))
    jobs["kmeans"] = JobSpec(
        name="K-Means", dataset=pt, iterations=10,
        params="10 iterations, 8 clusters",
        prep=(StageSpec("read-cache", 4.0, _scale(pt, 11.0), 0.4, io=0.9, cpu=0.3),
              StageSpec("init-centers", 1.0, _scale(pt, 0.6), 0.5,
                        cpu=0.4, shuffle=0.3)),
        iter_stages=(StageSpec("assign-points", 1.0, _scale(pt, 5.0), 0.0,
                               cpu=0.85, io=0.1),
                     StageSpec("update-centers", 0.6, _scale(pt, 0.5), 0.9,
                               cpu=0.3, shuffle=0.75)),
        final=(StageSpec("model-save", 1.5, 1.5, 0.2, io=0.6, cpu=0.2),))
    jobs["gbt"] = JobSpec(
        name="GBT", dataset=vm, iterations=10,
        params='10 iterations, "Regression" configuration',
        # GBT decomposes into many small stages per boosting round (paper:
        # "internally decomposed into many components")
        prep=(StageSpec("read-cache", 4.0, _scale(vm, 12.0), 0.4, io=0.9, cpu=0.3),
              StageSpec("bin-features", 2.0, _scale(vm, 2.2), 0.5, cpu=0.6)),
        iter_stages=(StageSpec("predict-residual", 0.8, _scale(vm, 1.6), 0.0,
                               cpu=0.8, io=0.1),
                     StageSpec("hist-level-1", 0.5, _scale(vm, 1.2), 0.7,
                               cpu=0.7, shuffle=0.6),
                     StageSpec("hist-level-2", 0.5, _scale(vm, 1.0), 0.7,
                               cpu=0.7, shuffle=0.6),
                     StageSpec("hist-level-3", 0.5, _scale(vm, 0.8), 0.7,
                               cpu=0.7, shuffle=0.6),
                     StageSpec("choose-splits", 0.4, _scale(vm, 0.2), 0.9,
                               cpu=0.3, shuffle=0.8)),
        final=(StageSpec("model-save", 1.5, 1.5, 0.2, io=0.6, cpu=0.2),))
    return jobs


JOBS = build_jobs()
SCALEOUT_RANGE = (4, 36)          # Spark executors (paper §V-A)


def scale_job(job: JobSpec, size_scale: float) -> JobSpec:
    """The same job on a ``size_scale``-times larger (or smaller) dataset:
    the data-dependent (perfectly-parallel) term of every stage scales with
    the input size while serial/communication terms stay fixed — the
    dataset-size axis of cross-context evaluation (C3O-style)."""
    import dataclasses

    def sc(stages):
        return tuple(dataclasses.replace(s, parallel=s.parallel * size_scale)
                     for s in stages)

    ds = dataclasses.replace(job.dataset,
                             size_gb=job.dataset.size_gb * size_scale)
    return dataclasses.replace(job, dataset=ds, prep=sc(job.prep),
                               iter_stages=sc(job.iter_stages),
                               final=sc(job.final))
