from repro.dataflow.context import ContextEncoder
from repro.dataflow.fleet import FleetCampaign
from repro.dataflow.runner import JobExperiment, RunStats, window_stats
from repro.dataflow.simulator import ClusterSim, RunRecord, rescale_overhead
from repro.dataflow.workloads import (DATASETS, JOBS, SCALEOUT_RANGE, JobSpec,
                                      StageSpec, make_multiclass, make_points,
                                      make_vandermonde, scale_job)

__all__ = ["ClusterSim", "ContextEncoder", "DATASETS", "FleetCampaign",
           "JOBS", "JobExperiment",
           "JobSpec", "RunRecord", "RunStats", "SCALEOUT_RANGE", "StageSpec",
           "make_multiclass", "make_points", "make_vandermonde",
           "rescale_overhead", "scale_job", "window_stats"]
