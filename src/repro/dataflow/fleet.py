"""Multi-job fleet campaigns over the shared decision service and a shared
simulation backend.

A :class:`FleetCampaign` owns one :class:`~repro.core.service.DecisionService`
shared by many :class:`~repro.dataflow.runner.JobExperiment`\\ s (four job
classes x several seeds, the paper's multi-tenant setting).  Each adaptive
run executes as a generator that yields its pending simulation step at every
component and its pending rescaling decision at every decision point; the
campaign interleaves all generators in lockstep rounds and hands EVERY
currently-pending request of each kind to its engine in one call — sim steps
ride one vectorized dispatch (``engine="batched"``) and same-bucket
decisions from different jobs ride a single jit dispatch, while each job
still sees its own model's predictions.

:meth:`FleetCampaign.arrival_campaign` adds the multi-tenant capacity model:
a global executor pool with Poisson job arrivals — concurrent jobs contend,
and every rescaling decision is capped to the job's fair share of the free
pool (``repro.core.service.apply_capacity``), so the compliant pick must
respect a shrinking max scale-out.  The invariant ``sum(allocations) <=
pool_size`` holds after every round: admission clamps the initial
allocation to the headroom, and the per-round caps hand each pending
decision ``alloc_i + free // n_pending``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.service import DecisionService, apply_capacity
from repro.dataflow.runner import JobExperiment, RunStats
from repro.dataflow.workloads import SCALEOUT_RANGE
from repro.sim.engine import BatchedClusterSim, SimStepRequest


@dataclass
class CapacityTrace:
    """Per-round pool accounting of an arrival campaign."""
    round_idx: int
    active: int
    pool_used: int
    pool_size: int
    capped_decisions: int = 0
    arrivals: int = 0


class FleetCampaign:
    """Drive many concurrent job experiments through one decision service.

    Pass ``engine="batched"`` to re-register every experiment on ONE shared
    :class:`BatchedClusterSim` (before any runs have started), so each
    lockstep round advances the whole fleet's simulation in one device
    dispatch.  The default keeps each experiment's own backend (the numpy
    per-job event loop), which is the baseline the scenario-suite benchmark
    compares against.
    """

    def __init__(self, experiments: Sequence[JobExperiment],
                 service: Optional[DecisionService] = None,
                 engine: Optional[str] = None):
        self.service = service or DecisionService()
        self.experiments = list(experiments)
        for exp in self.experiments:
            exp.service = self.service          # single-run calls batch too
        if engine == "batched":
            shared = BatchedClusterSim()
            for exp in self.experiments:
                assert exp._run_idx == 0, \
                    "attach the shared backend before any runs"
                exp.backend = shared
                exp.sim_slot = shared.register(exp.job, exp.seed,
                                               exp.scenario)

    def profile(self, n_runs: int = 10) -> None:
        for exp in self.experiments:
            exp.profile(n_runs)

    # ---------------------------------------------------------- round driver
    def _start(self, gens: Dict[int, object], stats: Dict[int, RunStats]
               ) -> Dict[int, object]:
        pending: Dict[int, object] = {}
        for i, gen in list(gens.items()):
            try:
                pending[i] = next(gen)
            except StopIteration as stop:       # run without any request
                stats[i] = stop.value
        return pending

    def _round(self, gens: Dict[int, object], pending: Dict[int, object],
               stats: Dict[int, RunStats],
               caps: Optional[Dict[int, int]] = None,
               on_decision=None) -> Tuple[Dict[int, object], int, List[int]]:
        """One lockstep round: batch pending sim steps per backend and
        pending decisions per shape bucket, resume every generator.

        ``caps`` (job id -> max scale-out) applies capacity caps to the
        listed decision requests; ``on_decision(i, result)`` observes each
        decision as it lands.  Returns (next pending, capped-decision
        count, ids of generators that finished this round).
        """
        results: Dict[int, object] = {}
        sims = {i: r for i, r in pending.items()
                if isinstance(r, SimStepRequest)}
        decs = {i: r for i, r in pending.items() if i not in sims}
        by_backend: Dict[int, List[int]] = {}
        for i in sims:
            by_backend.setdefault(
                id(self.experiments[i].backend), []).append(i)
        for ids in by_backend.values():
            backend = self.experiments[ids[0]].backend
            for i, res in zip(ids, backend.step([sims[i] for i in ids])):
                results[i] = res
        capped = 0
        if decs:
            ids = list(decs)
            reqs = []
            for i in ids:
                req = decs[i]
                if caps is not None and i in caps:
                    limited = apply_capacity(req, caps[i])
                    capped += limited is not req
                    req = limited
                reqs.append(req)
            for i, res in zip(ids, self.service.decide(reqs)):
                results[i] = res
                if on_decision is not None:
                    on_decision(i, res)
        nxt: Dict[int, object] = {}
        done: List[int] = []
        for i, res in results.items():
            try:
                nxt[i] = gens[i].send(res)
            except StopIteration as stop:
                stats[i] = stop.value
                done.append(i)
        return nxt, capped, done

    def _drain(self, gens: Dict[int, object]) -> Dict[int, RunStats]:
        """Interleave generators to completion, batching each round's
        pending requests per kind (and per sim backend)."""
        stats: Dict[int, RunStats] = {}
        pending = self._start(gens, stats)
        while pending:
            pending, _, _ = self._round(gens, pending, stats)
        return stats

    def adaptive_round(self, method: str = "enel",
                       inject_failures: bool = False) -> List[RunStats]:
        """One adaptive run of EVERY experiment, requests cross-batched.

        All experiments advance to their next pending request; each round
        the set of pending sim steps is executed in one backend call per
        backend and the set of pending decisions in one service call
        (grouped by shape bucket -> one jit dispatch per bucket), and each
        experiment resumes with its own result.  Returns the
        per-experiment RunStats in order.
        """
        gens = {i: exp.adaptive_run_gen(method, inject_failures)
                for i, exp in enumerate(self.experiments)}
        stats = self._drain(gens)
        return [stats[i] for i in range(len(self.experiments))]

    # ------------------------------------------------------ multi-tenant pool
    def arrival_campaign(self, *, pool_size: int, arrival_rate: float,
                         method: str = "enel", inject_failures: bool = False,
                         seed: int = 0, max_rounds: int = 64
                         ) -> Tuple[List[Optional[RunStats]],
                                    List[CapacityTrace]]:
        """Poisson arrivals into a bounded executor pool.

        Experiments queue up; each lockstep round admits ``~Poisson(rate)``
        waiting jobs (clamped to the pool headroom — a job needs at least
        the minimum scale-out), runs one interleaved round of every active
        job, and caps every pending decision at the job's current
        allocation plus its fair share of the free pool.  Jobs run one
        adaptive run each and release their executors on completion.
        """
        assert method == "enel", \
            "capacity caps ride the decision-service request path, which " \
            "only Enel uses (Ellis decides inline in the runner)"
        rng = np.random.RandomState(seed)
        s_min = SCALEOUT_RANGE[0]
        waiting = list(range(len(self.experiments)))
        gens: Dict[int, object] = {}
        pending: Dict[int, object] = {}
        # granted allocation per active job: updated the moment a pick is
        # granted (decision result) and re-confirmed by the next sim step,
        # so admissions never read a stale pool
        alloc: Dict[int, int] = {}
        stats_d: Dict[int, RunStats] = {}
        trace: List[CapacityTrace] = []

        def admit(row: CapacityTrace):
            n = int(rng.poisson(arrival_rate)) if arrival_rate > 0 \
                else len(waiting)
            for _ in range(n):
                if not waiting:
                    return
                free = pool_size - sum(alloc.values())
                if free < s_min:
                    return
                i = waiting.pop(0)
                exp = self.experiments[i]
                exp.scale_cap = free          # clamps the initial allocation
                gens[i] = exp.adaptive_run_gen(method, inject_failures)
                try:
                    pending[i] = next(gens[i])
                except StopIteration as stop:
                    stats_d[i] = stop.value
                    continue
                alloc[i] = int(getattr(pending[i], "end_scaleout", s_min))
                row.arrivals += 1

        for round_idx in range(max_rounds):
            row = CapacityTrace(round_idx, 0, 0, pool_size)
            admit(row)
            if not pending and not waiting:
                break
            for i, r in pending.items():      # granted picks take effect
                if isinstance(r, SimStepRequest):
                    alloc[i] = int(r.end_scaleout)
            dec_ids = [i for i, r in pending.items()
                       if not isinstance(r, SimStepRequest)]
            caps = None
            if dec_ids:
                free = max(0, pool_size - sum(alloc.values()))
                share = free // len(dec_ids)
                caps = {i: alloc.get(i, s_min) + share for i in dec_ids}

            def grant(i, res):                # reserve the pick immediately
                alloc[i] = int(res.scaleout)  # <= caps[i]: range floor 4 is
                # always a candidate, so apply_capacity's fallback (which
                # could exceed a sub-floor cap) cannot trigger here

            pending, capped, done = self._round(gens, pending, stats_d,
                                                caps=caps, on_decision=grant)
            row.capped_decisions = capped
            for i in done:                    # job done: release executors
                alloc.pop(i, None)
                self.experiments[i].scale_cap = None
            row.active = len(pending)
            row.pool_used = sum(alloc.values())
            trace.append(row)
            assert row.pool_used <= pool_size, "capacity model oversubscribed"
        for exp in self.experiments:          # max_rounds may strand actives
            exp.scale_cap = None
        stats = [stats_d.get(i) for i in range(len(self.experiments))]
        return stats, trace
