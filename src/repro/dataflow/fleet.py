"""Multi-job fleet campaigns over the shared decision service.

A :class:`FleetCampaign` owns one :class:`~repro.core.service.DecisionService`
shared by many :class:`~repro.dataflow.runner.JobExperiment`\\ s (four job
classes x several seeds, the paper's multi-tenant setting).  Each adaptive
run executes as a generator that yields its pending rescaling decision at
every component boundary; the campaign interleaves all generators and hands
EVERY currently-pending request to the service in one call, so same-bucket
decisions from different jobs ride a single jit dispatch while each job
still sees its own model's predictions.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.service import DecisionService
from repro.dataflow.runner import JobExperiment, RunStats


class FleetCampaign:
    """Drive many concurrent job experiments through one decision service."""

    def __init__(self, experiments: Sequence[JobExperiment],
                 service: Optional[DecisionService] = None):
        self.service = service or DecisionService()
        self.experiments = list(experiments)
        for exp in self.experiments:
            exp.service = self.service          # single-run calls batch too

    def profile(self, n_runs: int = 10) -> None:
        for exp in self.experiments:
            exp.profile(n_runs)

    def adaptive_round(self, method: str = "enel",
                       inject_failures: bool = False) -> List[RunStats]:
        """One adaptive run of EVERY experiment, decisions cross-batched.

        All experiments advance to their next decision point; the set of
        pending requests is decided in one service call (grouped by shape
        bucket -> one jit dispatch per bucket), and each experiment resumes
        with its own result.  Returns the per-experiment RunStats in order.
        """
        gens = {i: exp.adaptive_run_gen(method, inject_failures)
                for i, exp in enumerate(self.experiments)}
        stats: Dict[int, RunStats] = {}
        pending: Dict[int, object] = {}
        for i, gen in list(gens.items()):
            try:
                pending[i] = next(gen)
            except StopIteration as stop:       # run without any decision
                stats[i] = stop.value
        while pending:
            ids = list(pending)
            results = self.service.decide([pending[i] for i in ids])
            pending = {}
            for i, result in zip(ids, results):
                try:
                    pending[i] = gens[i].send(result)
                except StopIteration as stop:
                    stats[i] = stop.value
        return [stats[i] for i in range(len(self.experiments))]
