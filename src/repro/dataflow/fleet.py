"""Multi-job fleet campaigns over the shared decision service and a shared
simulation backend.

A :class:`FleetCampaign` owns one :class:`~repro.core.service.DecisionService`
shared by many :class:`~repro.dataflow.runner.JobExperiment`\\ s (four job
classes x several seeds, the paper's multi-tenant setting).  Each adaptive
run executes as a generator that yields its pending simulation step at every
component and its pending rescaling decision at every decision point; the
campaign interleaves all generators in lockstep rounds and hands EVERY
currently-pending request of each kind to its engine in one call — sim steps
ride one vectorized dispatch (``engine="batched"``) and same-bucket
decisions from different jobs ride a single jit dispatch, while each job
still sees its own model's predictions.

:meth:`FleetCampaign.arrival_campaign` adds the multi-tenant capacity model:
a global executor pool with Poisson job arrivals — concurrent jobs contend,
and every rescaling decision is capped to the job's fair share of the free
pool (``repro.core.service.apply_capacity``), so the compliant pick must
respect a shrinking max scale-out.  The invariant ``sum(allocations) <=
pool_size`` holds after every round: admission clamps the initial
allocation to the headroom, and the per-round caps hand each pending
decision ``alloc_i + free // n_pending``.
"""
from __future__ import annotations

import copy
import pickle
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.service import DecisionService, apply_capacity
from repro.dataflow.runner import JobExperiment, RunStats
from repro.dataflow.workloads import SCALEOUT_RANGE
from repro.sim.engine import BatchedClusterSim, SimStepRequest


@dataclass
class CapacityTrace:
    """Per-round pool accounting of an arrival campaign."""
    round_idx: int
    active: int
    pool_used: int
    pool_size: int
    capped_decisions: int = 0
    arrivals: int = 0


@dataclass
class CampaignCheckpoint:
    """Resumable snapshot of a fleet campaign between lockstep rounds.

    Mid-run generators cannot be pickled or rebuilt directly, so a
    checkpoint stores checkpoint-by-replay state instead: each running
    experiment's RUN-START snapshot plus the ordered log of results its
    generator consumed since (one per round).  Resuming restores the
    run-start state, re-creates the generator and replays the logged
    results — every host-side mutation the generator performs
    (``record_component``/``observe_component``, graph building) is
    deterministically re-applied, the sim backend is then overwritten
    with its checkpoint-time slot state (``backend_now``), and the
    generator is parked at exactly the request it was pending on.
    Experiments whose run already finished (and between-runs
    checkpoints) store their CURRENT state — no replay needed.
    """
    kind: str                              # "adaptive" | "arrival"
    method: str
    inject_failures: bool
    n_runs: int
    run_idx: int                           # completed runs so far
    round_idx: int                         # global lockstep round counter
    checkpoint_every: int
    mid_run: bool
    # per experiment: {state, log, backend_now, stats}; log is None when
    # the state is current (finished / between runs) and a replay list
    # (run-start state + consumed results) when the run is in flight
    exps: List[Dict] = field(default_factory=list)
    all_stats: List[List[RunStats]] = field(default_factory=list)
    service_state: Dict = field(default_factory=dict)
    extra: Optional[Dict] = None           # arrival-campaign pool state
    obs_state: Optional[Dict] = None       # registry + flight-recorder state

    def save(self, path: str) -> None:
        """Persist to disk (host arrays only — snapshots are numpy)."""
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "CampaignCheckpoint":
        with open(path, "rb") as f:
            return pickle.load(f)


@dataclass
class FusedCheckpoint:
    """Resumable snapshot of a fused (single-scan) campaign.

    The whole mutable state of a fused campaign is the scan carry — a host
    copy of it plus the step index is a complete checkpoint.  ``ys`` holds
    the stacked per-step outputs for steps ``[0, step)`` so a resumed
    campaign can materialize the SAME traces as an uninterrupted one.
    """
    step: int
    n_steps: int
    carry: Dict
    ys: Dict
    obs_state: Optional[Dict] = None       # registry + flight-recorder state

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "FusedCheckpoint":
        with open(path, "rb") as f:
            return pickle.load(f)


@dataclass
class FusedReport:
    """Campaign-level outcome of a fused run: the plan it executed, the
    final host carry, the per-step host traces and the guardrail counters
    (``nonfinite`` MUST be all-zero — the in-scan isfinite reduce clamps
    any non-finite pick to the current scale-out and counts it here)."""
    plan: object
    carry: Dict
    ys: Dict
    fallbacks: np.ndarray        # (J,) fallback-clamped decisions
    nonfinite: np.ndarray        # (J,) non-finite sweep picks (clamped)
    checkpoints: List[FusedCheckpoint] = field(default_factory=list)


def materialize_fused(plan, ys) -> List[List[RunStats]]:
    """Host materialization of a fused campaign's traces: one
    :class:`RunStats` per (run, experiment), shaped like
    :meth:`FleetCampaign.adaptive_campaign`'s stats.  Pure host numpy —
    called ONCE at campaign end (or resume), never inside the scan."""
    host = plan.host
    c_max = plan.static.c_max
    n_runs = host["n_runs"]
    J = len(host["job_names"])
    clock = np.asarray(ys["clock"])
    z = np.asarray(ys["z"])
    s_next = np.asarray(ys["s_next"])
    decided = np.asarray(ys["decided"])
    fallback = np.asarray(ys["fallback"])
    failed = np.asarray(ys["failed"])            # (T, s_max, J)
    stage_live = (np.arange(failed.shape[1])[None, :, None]
                  < host["n_stage"][:, None, :])  # (c_max, s_max, J)
    stage_live = np.tile(stage_live, (n_runs, 1, 1))
    all_stats: List[List[RunStats]] = []
    for r in range(n_runs):
        t0 = r * c_max
        row: List[RunStats] = []
        for j in range(J):
            nc = int(host["n_comp"][j])
            runtime = float(clock[t0 + nc - 1, j])
            target = float(host["targets"][j])
            scaleouts = [int(host["s0"][j])]
            for t in range(t0, t0 + nc):
                if decided[t, j] and s_next[t, j] != z[t, j]:
                    scaleouts.append(int(s_next[t, j]))
            nfail = int(np.sum(
                failed[t0:t0 + c_max] * stage_live[t0:t0 + c_max],
                axis=(0, 1))[j])
            row.append(RunStats(
                host["run_idx0"][j] + r + 1, "enel", runtime, target,
                max(0.0, runtime - target),
                predicted=host["predicted"][j], scaleouts=scaleouts,
                n_failures=nfail, n_rescales=len(scaleouts) - 1,
                decide_calls=int(decided[t0:t0 + c_max, j].sum()),
                fallback_decisions=int(fallback[t0:t0 + c_max, j].sum())))
        all_stats.append(row)
    return all_stats


class FleetCampaign:
    """Drive many concurrent job experiments through one decision service.

    Pass ``engine="batched"`` to re-register every experiment on ONE shared
    :class:`BatchedClusterSim` (before any runs have started), so each
    lockstep round advances the whole fleet's simulation in one device
    dispatch.  The default keeps each experiment's own backend (the numpy
    per-job event loop), which is the baseline the scenario-suite benchmark
    compares against.
    """

    def __init__(self, experiments: Sequence[JobExperiment],
                 service: Optional[DecisionService] = None,
                 engine: Optional[str] = None):
        self.service = service or DecisionService()
        self.experiments = list(experiments)
        for exp in self.experiments:
            exp.service = self.service          # single-run calls batch too
        if engine == "batched":
            shared = BatchedClusterSim()
            for exp in self.experiments:
                assert exp._run_idx == 0, \
                    "attach the shared backend before any runs"
                exp.backend = shared
                exp.sim_slot = shared.register(exp.job, exp.seed,
                                               exp.scenario)

    def profile(self, n_runs: int = 10) -> None:
        for exp in self.experiments:
            exp.profile(n_runs)

    # ---------------------------------------------------------- round driver
    def _start(self, gens: Dict[int, object], stats: Dict[int, RunStats]
               ) -> Dict[int, object]:
        pending: Dict[int, object] = {}
        for i, gen in list(gens.items()):
            try:
                pending[i] = next(gen)
            except StopIteration as stop:       # run without any request
                stats[i] = stop.value
        return pending

    def _round(self, gens: Dict[int, object], pending: Dict[int, object],
               stats: Dict[int, RunStats],
               caps: Optional[Dict[int, int]] = None,
               on_decision=None,
               on_result=None) -> Tuple[Dict[int, object], int, List[int]]:
        """One lockstep round: batch pending sim steps per backend and
        pending decisions per shape bucket, resume every generator.

        ``caps`` (job id -> max scale-out) applies capacity caps to the
        listed decision requests; ``on_decision(i, result)`` observes each
        decision as it lands; ``on_result(i, result)`` observes EVERY
        result (sim step or decision) just before it is fed to generator
        ``i`` — the checkpoint event log.  Returns (next pending,
        capped-decision count, ids of generators that finished this round).
        """
        results: Dict[int, object] = {}
        sims = {i: r for i, r in pending.items()
                if isinstance(r, SimStepRequest)}
        decs = {i: r for i, r in pending.items() if i not in sims}
        by_backend: Dict[int, List[int]] = {}
        for i in sims:
            by_backend.setdefault(
                id(self.experiments[i].backend), []).append(i)
        for ids in by_backend.values():
            backend = self.experiments[ids[0]].backend
            for i, res in zip(ids, backend.step([sims[i] for i in ids])):
                results[i] = res
        capped = 0
        if decs:
            ids = list(decs)
            reqs = []
            for i in ids:
                req = decs[i]
                if caps is not None and i in caps:
                    limited = apply_capacity(req, caps[i])
                    capped += limited is not req
                    req = limited
                reqs.append(req)
            for i, res in zip(ids, self.service.decide(reqs)):
                results[i] = res
                if on_decision is not None:
                    on_decision(i, res)
        nxt: Dict[int, object] = {}
        done: List[int] = []
        for i, res in results.items():
            if on_result is not None:
                on_result(i, res)
            try:
                nxt[i] = gens[i].send(res)
            except StopIteration as stop:
                stats[i] = stop.value
                done.append(i)
        return nxt, capped, done

    def _drain(self, gens: Dict[int, object]) -> Dict[int, RunStats]:
        """Interleave generators to completion, batching each round's
        pending requests per kind (and per sim backend)."""
        stats: Dict[int, RunStats] = {}
        pending = self._start(gens, stats)
        while pending:
            pending, _, _ = self._round(gens, pending, stats)
        return stats

    def adaptive_round(self, method: str = "enel",
                       inject_failures: bool = False) -> List[RunStats]:
        """One adaptive run of EVERY experiment, requests cross-batched.

        All experiments advance to their next pending request; each round
        the set of pending sim steps is executed in one backend call per
        backend and the set of pending decisions in one service call
        (grouped by shape bucket -> one jit dispatch per bucket), and each
        experiment resumes with its own result.  Returns the
        per-experiment RunStats in order.
        """
        stats, _ = self.adaptive_campaign(1, method, inject_failures)
        return stats[0]

    # ------------------------------------------------------ checkpointed runs
    def adaptive_campaign(self, n_runs: int, method: str = "enel",
                          inject_failures: bool = False, *,
                          checkpoint_every: int = 0,
                          stop_after_round: Optional[int] = None
                          ) -> Tuple[Optional[List[List[RunStats]]],
                                     List[CampaignCheckpoint]]:
        """``n_runs`` adaptive runs of every experiment with optional
        periodic checkpoints.

        ``checkpoint_every=k`` snapshots the whole campaign every k
        lockstep rounds (plus one initial checkpoint), cheap enough to
        leave off (0) on the hot path — no snapshot or event-log work
        happens then.  ``stop_after_round=r`` simulates a controller
        crash: the campaign halts after global round r WITHOUT writing a
        checkpoint and returns ``(None, ckpts)`` — resume from the last
        periodic checkpoint with :meth:`resume_adaptive_campaign`.

        Returns ``(stats, ckpts)`` where ``stats[run][i]`` is experiment
        i's RunStats for that run (or None if stopped early).
        """
        return self._campaign_loop(
            n_runs, method, inject_failures, checkpoint_every,
            stop_after_round, run_idx=0, round_idx=0, all_stats=[],
            ckpts=[])

    def _campaign_loop(self, n_runs, method, inject_failures,
                       checkpoint_every, stop_after_round, *, run_idx,
                       round_idx, all_stats, ckpts, gens=None, pending=None,
                       stats=None, runstart=None, logs=None):
        checkpointing = checkpoint_every > 0
        mid = gens is not None
        while run_idx < n_runs or mid:
            if not mid:
                stats = {}
                if checkpointing:
                    runstart = {i: exp.snapshot_state()
                                for i, exp in enumerate(self.experiments)}
                    logs = {i: [] for i in range(len(self.experiments))}
                gens = {i: exp.adaptive_run_gen(method, inject_failures)
                        for i, exp in enumerate(self.experiments)}
                pending = self._start(gens, stats)
                if checkpointing and not ckpts:
                    # initial checkpoint: a crash before the first periodic
                    # one must still be recoverable
                    ckpts.append(self._make_checkpoint(
                        method, inject_failures, n_runs, run_idx, round_idx,
                        checkpoint_every, all_stats, stats, runstart, logs,
                        pending))
            mid = False
            while pending:
                on_result = None
                if checkpointing:
                    on_result = lambda i, res: logs[i].append(res)
                pending, _, _ = self._round(gens, pending, stats,
                                            on_result=on_result)
                round_idx += 1
                if checkpointing and round_idx % checkpoint_every == 0:
                    ckpts.append(self._make_checkpoint(
                        method, inject_failures, n_runs, run_idx, round_idx,
                        checkpoint_every, all_stats, stats, runstart, logs,
                        pending))
                if stop_after_round is not None and \
                        round_idx >= stop_after_round:
                    return None, ckpts           # simulated controller crash
            all_stats.append([stats[i]
                              for i in range(len(self.experiments))])
            run_idx += 1
        return all_stats, ckpts

    def _make_checkpoint(self, method, inject_failures, n_runs, run_idx,
                         round_idx, checkpoint_every, all_stats, stats,
                         runstart, logs, pending, kind="adaptive",
                         extra=None) -> CampaignCheckpoint:
        mid = bool(pending)
        all_c = copy.deepcopy(all_stats)
        if not mid and stats and len(stats) == len(self.experiments):
            # the round that tripped the checkpoint completed the run:
            # fold it in so resume starts cleanly at the next run
            all_c.append([copy.deepcopy(stats[i])
                          for i in range(len(self.experiments))])
            run_idx += 1
        exps = []
        for i, exp in enumerate(self.experiments):
            if mid and i in pending:
                exps.append({
                    "state": runstart[i], "log": list(logs[i]),
                    "backend_now": exp.backend.slot_state(exp.sim_slot),
                    "stats": None})
            else:                      # finished this run / between runs
                exps.append({
                    "state": exp.snapshot_state(), "log": None,
                    "backend_now": None,
                    "stats": copy.deepcopy(stats.get(i)) if mid else None})
        obs.emit("checkpoint", kind=kind, run_idx=run_idx,
                 round_idx=round_idx, mid_run=mid)
        return CampaignCheckpoint(
            kind=kind, method=method, inject_failures=inject_failures,
            n_runs=n_runs, run_idx=run_idx, round_idx=round_idx,
            checkpoint_every=checkpoint_every, mid_run=mid, exps=exps,
            all_stats=all_c, service_state=self.service.snapshot_state(),
            extra=copy.deepcopy(extra),
            obs_state=obs.snapshot() if obs.enabled() else None)

    def _replay_exp(self, i: int, entry: Dict, method: str,
                    inject_failures: bool):
        """Rebuild one mid-run generator from its run-start snapshot by
        replaying its consumed results, then pin the backend slot to its
        checkpoint-time state.  Returns (gen, pending request)."""
        exp = self.experiments[i]
        exp.restore_state(entry["state"])
        gen = exp.adaptive_run_gen(method, inject_failures)
        req = next(gen)
        for res in entry["log"]:
            req = gen.send(res)
        # replay fed logged results without touching the sim — overwrite
        # with the slot state as of the checkpoint (rng stream, clock,
        # noise block) so post-resume steps continue the exact sequence
        exp.backend.restore_slot(exp.sim_slot, entry["backend_now"])
        return gen, req

    def resume_adaptive_campaign(self, ckpt: CampaignCheckpoint, *,
                                 stop_after_round: Optional[int] = None
                                 ) -> Tuple[Optional[List[List[RunStats]]],
                                            List[CampaignCheckpoint]]:
        """Continue a campaign from a checkpoint; the completed campaign's
        stats (and decision traces) match an uninterrupted run exactly."""
        assert ckpt.kind == "adaptive", "use resume_arrival_campaign"
        if ckpt.obs_state is not None and obs.enabled():
            # rewind the registry + recorder to checkpoint time so the
            # resumed campaign's span/metric stream continues exactly
            # where the checkpointed one left off (trace identity)
            obs.restore(ckpt.obs_state)
        obs.emit("restore", kind="adaptive", run_idx=ckpt.run_idx,
                 round_idx=ckpt.round_idx, mid_run=ckpt.mid_run)
        self.service.restore_state(ckpt.service_state)
        all_stats = copy.deepcopy(ckpt.all_stats)
        if not ckpt.mid_run:
            for i, entry in enumerate(ckpt.exps):
                self.experiments[i].restore_state(entry["state"])
            return self._campaign_loop(
                ckpt.n_runs, ckpt.method, ckpt.inject_failures,
                ckpt.checkpoint_every, stop_after_round,
                run_idx=ckpt.run_idx, round_idx=ckpt.round_idx,
                all_stats=all_stats, ckpts=[])
        stats, gens, pending, runstart, logs = {}, {}, {}, {}, {}
        for i, entry in enumerate(ckpt.exps):
            if entry["log"] is None:           # finished before checkpoint
                self.experiments[i].restore_state(entry["state"])
                stats[i] = copy.deepcopy(entry["stats"])
            else:
                gens[i], pending[i] = self._replay_exp(
                    i, entry, ckpt.method, ckpt.inject_failures)
                runstart[i] = entry["state"]
                logs[i] = list(entry["log"])
        return self._campaign_loop(
            ckpt.n_runs, ckpt.method, ckpt.inject_failures,
            ckpt.checkpoint_every, stop_after_round, run_idx=ckpt.run_idx,
            round_idx=ckpt.round_idx, all_stats=all_stats, ckpts=[],
            gens=gens, pending=pending, stats=stats, runstart=runstart,
            logs=logs)

    def adaptive_campaign_resilient(self, n_runs: int, method: str = "enel",
                                    inject_failures: bool = False, *,
                                    crash_rounds: Sequence[int] = (),
                                    checkpoint_every: int = 1
                                    ) -> Tuple[List[List[RunStats]], int]:
        """Run a campaign through a schedule of simulated controller
        crashes, restoring from the latest checkpoint after each one.
        Returns ``(stats, n_restores)``; stats match an uninterrupted
        campaign exactly (the checkpoint/replay contract under test in the
        chaos suite)."""
        crash_rounds = sorted(int(r) for r in crash_rounds)
        k = 0
        stop = crash_rounds[k] if k < len(crash_rounds) else None
        stats, ckpts = self.adaptive_campaign(
            n_runs, method, inject_failures,
            checkpoint_every=checkpoint_every, stop_after_round=stop)
        latest = list(ckpts)
        restores = 0
        while stats is None:
            restores += 1
            k += 1
            stop = crash_rounds[k] if k < len(crash_rounds) else None
            stats, ckpts = self.resume_adaptive_campaign(
                latest[-1], stop_after_round=stop)
            latest.extend(ckpts)
        return stats, restores

    # ------------------------------------------------------- fused campaigns
    def fused_campaign(self, n_runs: int, method: str = "enel",
                       inject_failures: bool = False, *,
                       write_back: bool = True,
                       checkpoint_every_runs: int = 0,
                       plan=None
                       ) -> Tuple[List[List[RunStats]], FusedReport]:
        """``n_runs`` adaptive runs of the whole fleet in ONE scanned jit.

        The stepped path (:meth:`adaptive_campaign`) re-enters python
        between every component; this compiles the entire campaign —
        sim step + ring append + decision sweep + per-run resident fit —
        into one ``lax.scan`` (``repro.core.campaign_kernel``) and
        materializes the traces once at the end.  Decisions are guarded
        in-scan: a non-compliant sweep falls back to the model-free pick
        and a non-finite pick is clamped to the current scale-out
        (counted in ``report.nonfinite``, asserted zero in CI).

        ``checkpoint_every_runs=k`` splits the scan every k runs and
        snapshots the carry (:class:`FusedCheckpoint`) — resume with
        :meth:`resume_fused_campaign` for traces identical to an
        uninterrupted campaign.  ``write_back=True`` syncs the final
        model/ring/backend state into the experiments, so stepped runs
        can continue after a fused campaign.
        """
        assert method == "enel", "the fused kernel scans Enel's sweep"
        from repro.core import campaign_kernel as ck
        if plan is None:
            plan = ck.build_plan(self.experiments, n_runs,
                                 inject_failures=inject_failures)
        carry = ck.init_carry(plan)
        return self._fused_drive(ck, plan, carry, start=0, pieces=[],
                                 ckpts=[],
                                 checkpoint_every_runs=checkpoint_every_runs,
                                 write_back=write_back)

    def resume_fused_campaign(self, plan, ckpt: FusedCheckpoint, *,
                              write_back: bool = True,
                              checkpoint_every_runs: int = 0
                              ) -> Tuple[List[List[RunStats]], FusedReport]:
        """Continue a fused campaign from a :class:`FusedCheckpoint`; the
        completed campaign's stats match an uninterrupted one exactly."""
        from repro.core import campaign_kernel as ck
        if ckpt.obs_state is not None and obs.enabled():
            obs.restore(ckpt.obs_state)
        obs.emit("restore", kind="fused", step=ckpt.step,
                 n_steps=ckpt.n_steps)
        carry = ck.carry_from_host(ckpt.carry)
        return self._fused_drive(
            ck, plan, carry, start=ckpt.step, pieces=[ckpt.ys],
            ckpts=[], checkpoint_every_runs=checkpoint_every_runs,
            write_back=write_back)

    def _fused_drive(self, ck, plan, carry, *, start, pieces, ckpts,
                     checkpoint_every_runs, write_back):
        import jax
        to_host = lambda tree: jax.tree_util.tree_map(np.asarray, tree)
        cat = lambda ps: {k: np.concatenate([p[k] for p in ps])
                          for k in ps[0]}
        seg = (checkpoint_every_runs * plan.static.c_max
               if checkpoint_every_runs > 0 else plan.n_steps)
        t = start
        while t < plan.n_steps:
            t1 = min(t + seg, plan.n_steps)
            carry, ys = ck.run_fused(plan, carry, t, t1)
            pieces.append(to_host(ys))
            t = t1
            if checkpoint_every_runs > 0 and t < plan.n_steps:
                obs.emit("checkpoint", kind="fused", step=t,
                         n_steps=plan.n_steps)
                ckpts.append(FusedCheckpoint(
                    step=t, n_steps=plan.n_steps,
                    carry=ck.carry_to_host(carry), ys=cat(pieces),
                    obs_state=obs.snapshot() if obs.enabled() else None))
        ys_all = cat(pieces)
        stats = materialize_fused(plan, ys_all)
        carry_h = ck.carry_to_host(carry)
        report = FusedReport(
            plan=plan, carry=carry_h, ys=ys_all,
            fallbacks=np.asarray(carry_h["fallbacks"]),
            nonfinite=np.asarray(carry_h["nonfinite"]), checkpoints=ckpts)
        if write_back:
            self._fused_write_back(plan, carry_h, stats, ys=ys_all)
        return stats, report

    def _fused_write_back(self, plan, carry: Dict,
                          stats: List[List[RunStats]],
                          ys: Optional[Dict] = None) -> None:
        """Sync the scan's final state into the host experiments: model
        params/opt, the resident training ring, run counters, per-run
        stats, and the backend slots' clock/interference carry (the RNG
        streams were already advanced by ``campaign_run_blocks``).  The
        host ``graph_history`` / Enel ``hist_summaries`` are NOT
        back-filled — a fused campaign trades those growing host
        mirrors for the single-dispatch hot path (documented deviation).
        """
        import jax
        import jax.numpy as jnp
        if ys is not None and obs.enabled():
            # the in-scan telemetry block becomes the same span stream the
            # stepped driver would have produced (parity-tested)
            from repro.core import campaign_kernel as ck
            ck.replay_spans(plan, ys)
        n_runs = plan.host["n_runs"]
        for j, exp in enumerate(self.experiments):
            tr = exp.trainer
            tr.params = jax.tree_util.tree_map(
                lambda x: jnp.asarray(x[j]), carry["params"])
            tr.opt = jax.tree_util.tree_map(
                lambda x: jnp.asarray(x[j]), carry["opt"])
            tr._fit_calls = int(carry["fit_calls"][j])
            tr.runs_seen += n_runs
            cache = tr.cache
            ring = carry["ring"]
            cache.buffers = {k: jnp.asarray(v[j])
                             for k, v in ring["buffers"].items()}
            cache.pos = int(ring["pos"][j])
            cache.count = int(ring["count"][j])
            cache.slot_ok = np.asarray(ring["slot_ok"][j]).copy()
            nc = int(plan.host["n_comp"][j])
            cache.latest = ((cache.pos - nc + np.arange(nc))
                            % cache.capacity).astype(np.int64)
            exp._run_idx += n_runs
            exp.enel.fallback_decisions += int(carry["fallbacks"][j])
            for r in range(n_runs):
                exp.stats.append(stats[r][j])
            st = exp.backend.slot_state(exp.sim_slot)
            st["clock"] = np.float32(carry["clock"][j])
            st["interf"] = np.float32(carry["interf"][j])
            exp.backend.restore_slot(exp.sim_slot, st)

    # ------------------------------------------------------ multi-tenant pool
    def arrival_campaign(self, *, pool_size: int, arrival_rate: float,
                         method: str = "enel", inject_failures: bool = False,
                         seed: int = 0, max_rounds: int = 64,
                         checkpoint_every: int = 0,
                         stop_after_round: Optional[int] = None
                         ) -> Tuple[Optional[List[Optional[RunStats]]],
                                    List[CapacityTrace]]:
        """Poisson arrivals into a bounded executor pool.

        Experiments queue up; each lockstep round admits ``~Poisson(rate)``
        waiting jobs (clamped to the pool headroom — a job needs at least
        the minimum scale-out), runs one interleaved round of every active
        job, and caps every pending decision at the job's current
        allocation plus its fair share of the free pool.  Jobs run one
        adaptive run each and release their executors on completion.

        ``checkpoint_every=k`` snapshots the campaign (including the pool
        state — arrival queue, allocations, Poisson RNG, in-flight
        generators) every k rounds into ``self.checkpoints``;
        ``stop_after_round`` simulates a controller crash (returns
        ``(None, trace)``), recoverable via :meth:`resume_arrival_campaign`.
        """
        assert method == "enel", \
            "capacity caps ride the decision-service request path, which " \
            "only Enel uses (Ellis decides inline in the runner)"
        rng = np.random.RandomState(seed)
        self.checkpoints: List[CampaignCheckpoint] = []
        return self._arrival_loop(
            pool_size=pool_size, arrival_rate=arrival_rate, method=method,
            inject_failures=inject_failures, max_rounds=max_rounds,
            checkpoint_every=checkpoint_every,
            stop_after_round=stop_after_round, rng=rng,
            waiting=list(range(len(self.experiments))), gens={}, pending={},
            alloc={}, stats_d={}, trace=[], round0=0, runstart={}, logs={})

    def resume_arrival_campaign(self, ckpt: CampaignCheckpoint
                                ) -> Tuple[Optional[List[Optional[RunStats]]],
                                           List[CapacityTrace]]:
        """Continue an arrival campaign from a checkpoint; the completed
        campaign's stats and capacity trace match an uninterrupted run."""
        assert ckpt.kind == "arrival", "use resume_adaptive_campaign"
        if ckpt.obs_state is not None and obs.enabled():
            obs.restore(ckpt.obs_state)
        obs.emit("restore", kind="arrival", run_idx=ckpt.run_idx,
                 round_idx=ckpt.round_idx, mid_run=ckpt.mid_run)
        self.service.restore_state(ckpt.service_state)
        ex = copy.deepcopy(ckpt.extra)
        rng = np.random.RandomState(0)
        rng.set_state(ex["rng"])
        gens, pending, runstart, logs = {}, {}, {}, {}
        for i, entry in enumerate(ckpt.exps):
            if entry["log"] is None:
                self.experiments[i].restore_state(entry["state"])
            else:
                gens[i], pending[i] = self._replay_exp(
                    i, entry, ckpt.method, ckpt.inject_failures)
                runstart[i] = entry["state"]
                logs[i] = list(entry["log"])
        self.checkpoints = []
        return self._arrival_loop(
            pool_size=ex["pool_size"], arrival_rate=ex["arrival_rate"],
            method=ckpt.method, inject_failures=ckpt.inject_failures,
            max_rounds=ex["max_rounds"],
            checkpoint_every=ckpt.checkpoint_every, stop_after_round=None,
            rng=rng, waiting=ex["waiting"], gens=gens, pending=pending,
            alloc=ex["alloc"], stats_d=ex["stats_d"], trace=ex["trace"],
            round0=ckpt.round_idx, runstart=runstart, logs=logs)

    def _arrival_loop(self, *, pool_size, arrival_rate, method,
                      inject_failures, max_rounds, checkpoint_every,
                      stop_after_round, rng, waiting, gens, pending, alloc,
                      stats_d, trace, round0, runstart, logs):
        checkpointing = checkpoint_every > 0
        s_min = SCALEOUT_RANGE[0]

        def admit(row: CapacityTrace):
            n = int(rng.poisson(arrival_rate)) if arrival_rate > 0 \
                else len(waiting)
            for _ in range(n):
                if not waiting:
                    return
                free = pool_size - sum(alloc.values())
                if free < s_min:
                    return
                i = waiting.pop(0)
                exp = self.experiments[i]
                exp.scale_cap = free          # clamps the initial allocation
                if checkpointing:             # run-start snapshot for replay
                    runstart[i] = exp.snapshot_state()
                    logs[i] = []
                gens[i] = exp.adaptive_run_gen(method, inject_failures)
                try:
                    pending[i] = next(gens[i])
                except StopIteration as stop:
                    stats_d[i] = stop.value
                    continue
                alloc[i] = int(getattr(pending[i], "end_scaleout", s_min))
                row.arrivals += 1

        for round_idx in range(round0, max_rounds):
            row = CapacityTrace(round_idx, 0, 0, pool_size)
            admit(row)
            if not pending and not waiting:
                break
            for i, r in pending.items():      # granted picks take effect
                if isinstance(r, SimStepRequest):
                    alloc[i] = int(r.end_scaleout)
            dec_ids = [i for i, r in pending.items()
                       if not isinstance(r, SimStepRequest)]
            caps = None
            if dec_ids:
                free = max(0, pool_size - sum(alloc.values()))
                share = free // len(dec_ids)
                caps = {i: alloc.get(i, s_min) + share for i in dec_ids}

            def grant(i, res):                # reserve the pick immediately
                alloc[i] = int(res.scaleout)  # <= caps[i]: range floor 4 is
                # always a candidate, so apply_capacity's fallback (which
                # could exceed a sub-floor cap) cannot trigger here

            on_result = None
            if checkpointing:
                on_result = lambda i, res: logs[i].append(res)
            pending, capped, done = self._round(gens, pending, stats_d,
                                                caps=caps, on_decision=grant,
                                                on_result=on_result)
            row.capped_decisions = capped
            for i in done:                    # job done: release executors
                alloc.pop(i, None)
                self.experiments[i].scale_cap = None
            row.active = len(pending)
            row.pool_used = sum(alloc.values())
            trace.append(row)
            assert row.pool_used <= pool_size, "capacity model oversubscribed"
            rounds_done = round_idx + 1
            if checkpointing and rounds_done % checkpoint_every == 0:
                extra = {"pool_size": pool_size,
                         "arrival_rate": arrival_rate,
                         "max_rounds": max_rounds, "rng": rng.get_state(),
                         "waiting": list(waiting), "alloc": dict(alloc),
                         "stats_d": stats_d, "trace": trace}
                exps = []
                for i, exp in enumerate(self.experiments):
                    if i in pending:
                        exps.append({
                            "state": runstart[i], "log": list(logs[i]),
                            "backend_now":
                                exp.backend.slot_state(exp.sim_slot),
                            "stats": None})
                    else:
                        exps.append({"state": exp.snapshot_state(),
                                     "log": None, "backend_now": None,
                                     "stats": None})
                self.checkpoints.append(CampaignCheckpoint(
                    kind="arrival", method=method,
                    inject_failures=inject_failures, n_runs=1, run_idx=0,
                    round_idx=rounds_done,
                    checkpoint_every=checkpoint_every,
                    mid_run=bool(pending), exps=exps, all_stats=[],
                    service_state=self.service.snapshot_state(),
                    extra=copy.deepcopy(extra)))
            if stop_after_round is not None and \
                    rounds_done >= stop_after_round:
                return None, trace            # simulated controller crash
        for exp in self.experiments:          # max_rounds may strand actives
            exp.scale_cap = None
        stats = [stats_d.get(i) for i in range(len(self.experiments))]
        return stats, trace
