"""Context encoding (paper §III-C, eqs. 1-2).

Every descriptive property p is mapped to a fixed-size vector
``p_vec = [lambda, q_1..q_L]`` where q comes from

  hasher     textual properties: character cleansing -> n-gram extraction ->
             hashing-trick term counts -> projection onto the L2 unit sphere
  binarizer  natural numbers: base-2 digits (valid while p <= 2^L)

and ``lambda`` in {0,1} flags which method was used.  Encoding is host-side
numpy (deterministic across processes: md5, not python hash()).
"""
from __future__ import annotations

import hashlib
import re
from typing import Iterable, List, Union

import numpy as np

DEFAULT_L = 31          # q length; N = L + 1 with the lambda prefix
NGRAM = 3


def is_natural(p: Union[str, int, float]) -> bool:
    if isinstance(p, bool):
        return False
    if isinstance(p, (int, np.integer)):
        return int(p) >= 0
    return False


def _cleanse(text: str) -> str:
    return re.sub(r"[^a-z0-9 ]+", " ", str(text).lower()).strip()


def _ngrams(text: str, n: int = NGRAM) -> List[str]:
    toks = []
    for word in text.split():
        if len(word) < n:
            toks.append(word)
        else:
            toks.extend(word[i:i + n] for i in range(len(word) - n + 1))
    return toks


def _stable_bucket(term: str, L: int) -> int:
    digest = hashlib.md5(term.encode("utf-8")).digest()
    return int.from_bytes(digest[:4], "little") % L


def hasher(p: str, L: int = DEFAULT_L) -> np.ndarray:
    q = np.zeros(L, np.float32)
    for term in _ngrams(_cleanse(p)):
        q[_stable_bucket(term, L)] += 1.0
    norm = np.linalg.norm(q)
    if norm > 0:
        q /= norm                       # euclidean unit sphere (paper §III-C)
    return q


def binarizer(p: int, L: int = DEFAULT_L) -> np.ndarray:
    p = int(p)
    if p < 0 or p >= (1 << L):
        raise ValueError(f"binarizer domain: 0 <= p < 2^{L}, got {p}")
    bits = np.zeros(L, np.float32)
    for i in range(L):
        bits[i] = (p >> i) & 1
    return bits


def encode_property(p: Union[str, int], L: int = DEFAULT_L) -> np.ndarray:
    """eq. (1): [lambda, q_1..q_L]; lambda=1 -> binarizer, 0 -> hasher."""
    if is_natural(p):
        lam, q = 1.0, binarizer(p, L)
    else:
        lam, q = 0.0, hasher(str(p), L)
    return np.concatenate([[lam], q]).astype(np.float32)


def encode_properties(props: Iterable[Union[str, int]],
                      L: int = DEFAULT_L) -> np.ndarray:
    props = list(props)
    if not props:
        return np.zeros((0, L + 1), np.float32)
    return np.stack([encode_property(p, L) for p in props])
