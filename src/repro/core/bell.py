"""Bell runtime model [Thamsen et al., IPCCC'16] — used by Enel for the
initial resource allocation (paper §IV-A).

Bell cross-validates between (a) an Ernest-style parametric model
t(s) = th0 + th1/s + th2*log(s) + th3*s  (non-negative least squares via
projected lstsq) and (b) a non-parametric local model (inverse-distance
interpolation over observed scale-outs), picking the lower LOO-CV error.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def _features(s: np.ndarray) -> np.ndarray:
    s = np.asarray(s, np.float64)
    return np.stack([np.ones_like(s), 1.0 / s, np.log(s), s], axis=1)


def _nnls(A: np.ndarray, y: np.ndarray, iters: int = 200) -> np.ndarray:
    """Projected-gradient NNLS (tiny problems; no scipy in this image)."""
    theta = np.maximum(np.linalg.lstsq(A, y, rcond=None)[0], 0.0)
    lr = 1.0 / (np.linalg.norm(A, 2) ** 2 + 1e-9)
    for _ in range(iters):
        grad = A.T @ (A @ theta - y)
        theta = np.maximum(theta - lr * grad, 0.0)
    return theta


class ParametricModel:
    def __init__(self):
        self.theta: Optional[np.ndarray] = None

    def fit(self, s: np.ndarray, t: np.ndarray) -> "ParametricModel":
        self.theta = _nnls(_features(s), np.asarray(t, np.float64))
        return self

    def predict(self, s) -> np.ndarray:
        return _features(np.atleast_1d(s)) @ self.theta


class NonParametricModel:
    """Inverse-distance-weighted interpolation in scale-out space."""

    def __init__(self, power: float = 2.0):
        self.power = power
        self.s: Optional[np.ndarray] = None
        self.t: Optional[np.ndarray] = None

    def fit(self, s: np.ndarray, t: np.ndarray) -> "NonParametricModel":
        self.s = np.asarray(s, np.float64)
        self.t = np.asarray(t, np.float64)
        return self

    def predict(self, s) -> np.ndarray:
        s = np.atleast_1d(np.asarray(s, np.float64))
        d = np.abs(s[:, None] - self.s[None, :])
        w = 1.0 / np.maximum(d, 1e-9) ** self.power
        exact = d < 1e-9
        w = np.where(exact.any(axis=1, keepdims=True), exact.astype(float), w)
        return (w * self.t[None, :]).sum(1) / w.sum(1)


class BellModel:
    """CV-selected combination (paper [20]): the better of the two models."""

    def __init__(self):
        self.model = None
        self.choice = "parametric"

    def fit(self, s: Sequence[float], t: Sequence[float]) -> "BellModel":
        s = np.asarray(s, np.float64)
        t = np.asarray(t, np.float64)
        if len(s) < 3:
            self.model = NonParametricModel().fit(s, t)
            self.choice = "nonparametric"
            return self
        errs = {"parametric": 0.0, "nonparametric": 0.0}
        for i in range(len(s)):
            mask = np.arange(len(s)) != i
            pm = ParametricModel().fit(s[mask], t[mask])
            npm = NonParametricModel().fit(s[mask], t[mask])
            errs["parametric"] += float((pm.predict(s[i])[0] - t[i]) ** 2)
            errs["nonparametric"] += float((npm.predict(s[i])[0] - t[i]) ** 2)
        self.choice = min(errs, key=errs.get)
        cls = ParametricModel if self.choice == "parametric" else NonParametricModel
        self.model = cls().fit(s, t)
        return self

    def predict(self, s) -> np.ndarray:
        return self.model.predict(s)


def initial_scaleout(history: Sequence[Tuple[float, float]],
                     target_runtime: float,
                     scaleout_range: Tuple[int, int]) -> int:
    """Smallest scale-out whose Bell-predicted runtime meets the target;
    falls back to the runtime-minimizing scale-out."""
    s = np.array([h[0] for h in history])
    t = np.array([h[1] for h in history])
    bell = BellModel().fit(s, t)
    lo, hi = scaleout_range
    cand = np.arange(lo, hi + 1)
    pred = bell.predict(cand)
    feasible = cand[pred <= target_runtime]
    if len(feasible):
        return int(feasible.min())
    return int(cand[np.argmin(pred)])
