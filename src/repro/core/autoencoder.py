"""Auto-encoder for dense low-dimensional context embeddings (paper §III-C).

min || p - h(g(p)) ||^2 with encoder g: R^N -> R^M, decoder h, M << N.
Pure JAX; trained with Adam on the pool of encoded property vectors.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import DEFAULT_L

N_DIM = DEFAULT_L + 1
EMBED_DIM = 8


def init_autoencoder(key, n_dim: int = N_DIM, m_dim: int = EMBED_DIM,
                     hidden: int = 24) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = lambda k, i, o: jax.random.normal(k, (i, o), jnp.float32) / jnp.sqrt(i)
    return {
        "enc_w1": s(k1, n_dim, hidden), "enc_b1": jnp.zeros(hidden),
        "enc_w2": s(k2, hidden, m_dim), "enc_b2": jnp.zeros(m_dim),
        "dec_w1": s(k3, m_dim, hidden), "dec_b1": jnp.zeros(hidden),
        "dec_w2": s(k4, hidden, n_dim), "dec_b2": jnp.zeros(n_dim),
    }


def encode(params: Dict, p: jax.Array) -> jax.Array:
    h = jnp.tanh(p @ params["enc_w1"] + params["enc_b1"])
    return jnp.tanh(h @ params["enc_w2"] + params["enc_b2"])


def decode(params: Dict, e: jax.Array) -> jax.Array:
    h = jnp.tanh(e @ params["dec_w1"] + params["dec_b1"])
    return h @ params["dec_w2"] + params["dec_b2"]


def recon_loss(params: Dict, batch: jax.Array) -> jax.Array:
    return jnp.mean(jnp.square(decode(params, encode(params, batch)) - batch))


def _adam_update(params, opt, batch, lr):
    loss, g = jax.value_and_grad(recon_loss)(params, batch)
    mu, nu, t = opt
    t = t + 1
    mu = jax.tree_util.tree_map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
    nu = jax.tree_util.tree_map(lambda v, gg: 0.999 * v + 0.001 * gg * gg, nu, g)
    def upd(p, m, v):
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        return p - lr * mh / (jnp.sqrt(vh) + 1e-8)
    params = jax.tree_util.tree_map(upd, params, mu, nu)
    return params, (mu, nu, t), loss


@jax.jit
def _adam_run_fixed(params, opt, batch, lr):
    def body(carry, _):
        p, o = carry
        p, o, loss = _adam_update(p, o, batch, lr)
        return (p, o), loss
    (params, opt), losses = jax.lax.scan(body, (params, opt), None, length=100)
    return params, opt, losses[-1]


def train_autoencoder(vectors: np.ndarray, *, steps: int = 300,
                      lr: float = 1e-2, seed: int = 0
                      ) -> Tuple[Dict, float]:
    """Fit on the property-vector pool; returns (params, final_loss)."""
    key = jax.random.PRNGKey(seed)
    params = init_autoencoder(key)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    opt = (zeros, jax.tree_util.tree_map(jnp.zeros_like, params),
           jnp.zeros((), jnp.int32))
    batch = jnp.asarray(vectors)
    loss = jnp.inf
    for _ in range(max(1, steps // 100)):
        params, opt, loss = _adam_run_fixed(params, opt, batch, lr)
    return params, float(loss)


def embed_properties(params: Dict, vectors: np.ndarray) -> np.ndarray:
    if vectors.shape[0] == 0:
        return np.zeros((0, EMBED_DIM), np.float32)
    return np.asarray(encode(params, jnp.asarray(vectors)))
