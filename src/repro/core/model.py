"""Enel's graph-propagation prediction model (paper §III-D, eqs. 3-7).

Four 2-layer MLPs (f1..f4) + a GATv2-style attention vector define a spatial
GNN over padded component DAGs:

  eq.6  |e_ij| = softmax_j( a^T sigma( f3(x_i, x_j) ) ),  x = a_vec‖c‖z_vec
  eq.7  m_hat_i = sum_j |e_ij| * f4( f3(x_i,x_j), m_j )   (metric propagation)
  eq.3  o_hat_i = f1(c_i, m_i, a_vec_i, z_vec_i, r_i)     (rescale overhead)
  eq.4  t_hat_i = f2(c_i, m_i, z_vec_i, o_hat_i)          (node runtime)
  eq.5  tt_hat_i = t_hat_i + max_{j in N(i)} tt_hat_j     (critical path)

Metric propagation runs level-synchronously (fori over MAX_NODES levels) so
predictions flow to nodes whose real metrics are unobserved (future
iterations), exactly the paper's online-inference mode.  ~5k parameters —
"allows for training even using a CPU" (§IV-C).

Two inference entry points share the math:

* ``forward`` / ``forward_batch`` — the original per-graph path.
* ``forward_stacked`` — batched inference over stacked (B, N, ...) arrays.
  With the graph-prop kernel flag enabled (``ENEL_GRAPH_PROP_KERNEL=1`` or
  :func:`set_graph_prop_kernel`), eqs. 6-7 run as one fused Pallas kernel
  (``repro.kernels.graph_prop``); otherwise it is ``vmap(forward)``.  Both
  routes are differentiable — the kernel carries a custom VJP backed by a
  backward Pallas kernel — so training (``enel_loss``) goes through
  ``forward_stacked`` and honours the same flag.
* ``sweep_per_component`` — the batched candidate-sweep decision path: one
  candidate-invariant template + per-candidate deltas, assembled and
  evaluated inside a single jit (used by ``EnelScaler.recommend``).
"""
from __future__ import annotations

import functools
import os
from collections import Counter
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import CTX_DIM, MAX_NODES, N_METRICS

HIDDEN = 32
EDGE_DIM = 16
X_DIM = 3 + CTX_DIM + 3          # a_vec ‖ c ‖ z_vec
MAX_LEVELS = 8                   # longest DAG chain the propagation supports

# --------------------------------------------------------------- kernel flag
_USE_GRAPH_PROP_KERNEL = os.environ.get(
    "ENEL_GRAPH_PROP_KERNEL", "0").lower() in ("1", "true", "yes")


def set_graph_prop_kernel(enabled: bool) -> None:
    """Route batched inference (forward_stacked / sweep) through the fused
    Pallas graph-propagation kernel instead of inline jnp."""
    global _USE_GRAPH_PROP_KERNEL
    _USE_GRAPH_PROP_KERNEL = bool(enabled)


def graph_prop_kernel_enabled(override: Optional[bool] = None) -> bool:
    return _USE_GRAPH_PROP_KERNEL if override is None else bool(override)


# -------------------------------------------------------------- trace counter
# Every (re)compilation of a counted jit traces its Python body once, so a
# plain counter bumped inside the function IS a compile counter.  The fleet
# benchmark asserts a campaign-level budget against these (shape bucketing
# exists precisely to keep them bounded).
TRACE_COUNTS: Counter = Counter()


def record_trace(name: str) -> None:
    TRACE_COUNTS[name] += 1
    # mirror into the unified obs registry (same count, queryable alongside
    # the other controller metrics); TRACE_COUNTS stays the canonical API.
    from repro import obs
    if obs.enabled():
        obs.registry().counter(
            "enel_jit_traces_total", "jit retraces per instrumented fn"
        ).labels(fn=name).inc()


def trace_count(name: str) -> int:
    return TRACE_COUNTS[name]


def reset_trace_counts() -> None:
    TRACE_COUNTS.clear()


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (i, o), jnp.float32) / jnp.sqrt(i),
             "b": jnp.zeros(o, jnp.float32)}
            for k, i, o in zip(ks, dims[:-1], dims[1:])]


def _mlp(layers, x, final_linear=True):
    for li, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if li < len(layers) - 1 or not final_linear:
            x = jax.nn.leaky_relu(x, 0.1)
    return x


def init_enel(key) -> Dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        # eq.3: f1(c, m, a_vec, z_vec, r) -> overhead
        "f1": _mlp_init(k1, [CTX_DIM + N_METRICS + 3 + 3 + 1, HIDDEN, 1]),
        # eq.4: f2(c, m, z_vec, o_hat) -> runtime
        "f2": _mlp_init(k2, [CTX_DIM + N_METRICS + 3 + 1, HIDDEN, 1]),
        # eq.6: f3(x_i, x_j) -> edge hidden
        "f3": _mlp_init(k3, [2 * X_DIM, HIDDEN, EDGE_DIM]),
        # eq.7: f4(edge hidden, m_j) -> propagated metrics
        "f4": _mlp_init(k4, [EDGE_DIM + N_METRICS, HIDDEN, N_METRICS]),
        "attn_a": jax.random.normal(k5, (EDGE_DIM,), jnp.float32) / 4.0,
    }


def n_params(params: Dict) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params))


def scaleout_vec(s: jax.Array) -> jax.Array:
    s = jnp.maximum(s, 1e-6)
    return jnp.stack([1.0 - 1.0 / s, jnp.log(s), s], axis=-1)


def _prelude(g: Dict) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Shared input lift; works on single (N, ...) and stacked (B, N, ...)."""
    a_vec = scaleout_vec(g["a_raw"])
    z_vec = scaleout_vec(g["z_raw"])
    x = jnp.concatenate([a_vec, g["context"], z_vec], axis=-1)
    adj = g["adj"] & g["mask"][..., None, :] & g["mask"][..., :, None]
    return a_vec, z_vec, x, adj


def _edge_hidden(params, x):
    """f3 on all (i, j) pairs -> (N, N, EDGE_DIM); i = dst, j = src."""
    n = x.shape[0]
    xi = jnp.broadcast_to(x[:, None, :], (n, n, x.shape[-1]))
    xj = jnp.broadcast_to(x[None, :, :], (n, n, x.shape[-1]))
    return _mlp(params["f3"], jnp.concatenate([xi, xj], axis=-1))


def edge_weights(params, x, adj) -> Tuple[jax.Array, jax.Array]:
    """eq.6: masked softmax over predecessors. Returns (e (N,N), h3 (N,N,E))."""
    h3 = _edge_hidden(params, x)
    logits = jnp.einsum("ije,e->ij", jax.nn.leaky_relu(h3, 0.1),
                        params["attn_a"])
    logits = jnp.where(adj, logits, -1e30)
    has_pred = adj.any(axis=1, keepdims=True)
    e = jax.nn.softmax(logits, axis=1)
    return jnp.where(has_pred, e, 0.0), h3


def _propagate(params, x, adj, m_obs, valid,
               levels: int = MAX_LEVELS) -> Tuple[jax.Array, jax.Array]:
    """eqs. 6-7 for ONE graph: edge weights + level-synchronous metric
    propagation (observed metrics are fixed inputs; unobserved nodes adopt
    propagated estimates as they stabilize).  Returns (e, m_hat).

    f4's first layer is split so the level-invariant h3 @ W_h half runs once
    outside the loop; per level only the (N, M) @ W_m half is recomputed.
    ``levels`` may be lowered to the graph's actual DAG depth — propagation
    reaches a fixed point after `depth` rounds, so fewer rounds are exact.
    """
    e, h3 = edge_weights(params, x, adj)
    w0, b0 = params["f4"][0]["w"], params["f4"][0]["b"]
    pre_h = h3 @ w0[:EDGE_DIM]                               # (N, N, HIDDEN)
    w_m = w0[EDGE_DIM:]
    f4_tail = params["f4"][1:]

    def level_step(_, m_cur):
        mj = jnp.where(valid[:, None], m_obs, m_cur)            # (N, M)
        hidden = jax.nn.leaky_relu(pre_h + (mj @ w_m)[None, :, :] + b0, 0.1)
        msg = _mlp(f4_tail, hidden)                              # (N,N,M)
        m_prop = jnp.einsum("ij,ijm->im", e, msg)
        return jnp.where(valid[:, None], m_obs, m_prop)

    m_hat = jax.lax.fori_loop(0, levels, level_step, m_obs)
    return e, m_hat


def _readout(params, g, a_vec, z_vec, adj, e, m_hat,
             levels: int = MAX_LEVELS) -> Dict[str, jax.Array]:
    """eqs. 3-5 for ONE graph given propagated metrics and edge weights.

    ``levels`` bounds the eq.5 accumulation rounds; the longest real-edge
    chain never exceeds the propagation depth, so a depth-lowered value is
    exact (same fixed-point argument as :func:`_propagate`).
    """
    valid = g["metrics_valid"]
    m_used = jnp.where(valid[:, None], g["metrics"], m_hat)

    # eq.3 overhead
    f1_in = jnp.concatenate([g["context"], m_used, a_vec, z_vec,
                             g["r"][:, None]], axis=-1)
    o_hat = _mlp(params["f1"], f1_in)[:, 0]

    # eq.4 runtime (end scale-out only + predicted overhead)
    f2_in = jnp.concatenate([g["context"], m_used, z_vec,
                             o_hat[:, None]], axis=-1)
    t_hat = jax.nn.softplus(_mlp(params["f2"], f2_in)[:, 0])

    # eq.5 accumulated runtime over the DAG (summary nodes excluded)
    t_node = jnp.where(g["mask"] & ~g["is_summary"], t_hat, 0.0)
    real_edge = adj & ~g["is_summary"][None, :]       # drop summary precedents

    def acc_step(_, tt):
        pred_best = jnp.max(
            jnp.where(real_edge, tt[None, :], 0.0), axis=1)
        return t_node + pred_best

    tt_hat = jax.lax.fori_loop(0, levels, acc_step, t_node)
    tt_hat = jnp.where(g["mask"] & ~g["is_summary"], tt_hat, 0.0)

    return {"overhead": o_hat, "runtime": t_hat, "acc_runtime": tt_hat,
            "metrics": m_hat, "edges": e,
            "total_runtime": jnp.max(tt_hat)}


def forward(params: Dict, g: Dict,
            levels: int = MAX_LEVELS) -> Dict[str, jax.Array]:
    """Full propagation over one padded graph (dict of (N,...) arrays).

    Returns overhead/runtime/accumulated-runtime/propagated-metric predictions.
    """
    a_vec, z_vec, x, adj = _prelude(g)
    e, m_hat = _propagate(params, x, adj, g["metrics"], g["metrics_valid"],
                          levels)
    return _readout(params, g, a_vec, z_vec, adj, e, m_hat, levels)


forward_batch = jax.vmap(forward, in_axes=(None, 0))


def forward_stacked(params: Dict, batch: Dict,
                    use_kernel: Optional[bool] = None,
                    levels: int = MAX_LEVELS) -> Dict[str, jax.Array]:
    """Batched inference over stacked (B, N, ...) graph arrays.

    Dispatches eqs. 6-7 to the fused Pallas ``graph_prop`` kernel when the
    flag is on (resolved at trace time — callers that jit must pass the
    resolved flag as a static argument), else falls back to vmap(forward).
    """
    if not graph_prop_kernel_enabled(use_kernel):
        if levels == MAX_LEVELS:
            return forward_batch(params, batch)
        return jax.vmap(lambda p, g: forward(p, g, levels),
                        in_axes=(None, 0))(params, batch)
    from repro.kernels.graph_prop.ops import graph_prop
    a_vec, z_vec, x, adj = _prelude(batch)
    e, m_hat = graph_prop(params, x, adj, batch["metrics"],
                          batch["metrics_valid"], levels=levels)
    return jax.vmap(functools.partial(_readout, levels=levels),
                    in_axes=(None, 0, 0, 0, 0, 0, 0))(
        params, batch, a_vec, z_vec, adj, e, m_hat)


def predict_total_runtime(params: Dict, graphs: Dict,
                          use_kernel: Optional[bool] = None) -> jax.Array:
    """Total predicted runtime per component graph in a stacked batch."""
    return forward_stacked(params, graphs, use_kernel)["total_runtime"]


# ------------------------------------------------------- candidate sweep jit
def assemble_sweep_batch(base, h_onehot, deltas) -> Dict[str, jax.Array]:
    """Template + per-candidate deltas -> flat stacked (C*K, N, ...) batch.

    Shapes:

      base[...]           (K, N, ...)   candidate-invariant template
      h_onehot            (K, N)        H-summary slot indicator
      deltas["a_raw"|"z_raw"|"r"|"metrics_valid"]   (C, K, N)
      deltas["h_context"] (C, K, CTX)   per-candidate H-node context
      deltas["h_metrics"] (C, K, M)     per-candidate H-node metrics
    """
    c, k = deltas["a_raw"].shape[:2]
    n = base["mask"].shape[-1]
    oh = h_onehot[None, :, :, None]                         # (1, K, N, 1)
    ctx = (base["context"][None] * (1.0 - oh) +
           oh * deltas["h_context"][:, :, None, :])
    met = (base["metrics"][None] * (1.0 - oh) +
           oh * deltas["h_metrics"][:, :, None, :])
    batch = {
        "context": ctx, "metrics": met,
        "metrics_valid": deltas["metrics_valid"],
        "a_raw": deltas["a_raw"], "z_raw": deltas["z_raw"],
        "r": deltas["r"],
        "adj": jnp.broadcast_to(base["adj"][None], (c, k, n, n)),
        "mask": jnp.broadcast_to(base["mask"][None], (c, k, n)),
        "is_summary": jnp.broadcast_to(base["is_summary"][None], (c, k, n)),
    }
    return {key: v.reshape((c * k,) + v.shape[2:]) for key, v in batch.items()}


def _sweep_impl(params, base, h_onehot, deltas, use_kernel, levels):
    """Assemble all (candidate x component) graphs from template + deltas on
    device and evaluate them in one fused batch -> per-component totals
    (C, K)."""
    record_trace("sweep_per_component")
    c, k = deltas["a_raw"].shape[:2]
    flat = assemble_sweep_batch(base, h_onehot, deltas)
    total = forward_stacked(params, flat, use_kernel=use_kernel, levels=levels)
    return total["total_runtime"].reshape(c, k)


_sweep_jit = jax.jit(_sweep_impl, static_argnums=(4, 5))
# deltas are rebuilt host-side every decision -> safe to donate off-CPU
_sweep_jit_donated = jax.jit(_sweep_impl, static_argnums=(4, 5),
                             donate_argnums=(3,))


@functools.lru_cache(maxsize=1)
def _sweep_fn():
    return _sweep_jit if jax.default_backend() == "cpu" else _sweep_jit_donated


def sweep_per_component(params: Dict, base: Dict, h_onehot, deltas,
                        use_kernel: Optional[bool] = None,
                        levels: int = MAX_LEVELS) -> jax.Array:
    """Jitted batched candidate sweep -> per-component totals (C, K)."""
    return _sweep_fn()(params, base, h_onehot, deltas,
                       graph_prop_kernel_enabled(use_kernel), levels)


# ---------------------------------------------------- sparse-edge sweep engine
# The component DAGs are near-chains: a graph holds at most a handful of real
# edges, yet the dense engine evaluates f3/f4 on all N x N node pairs and
# masks the rest away.  The fleet decision service instead gathers the few
# real (dst, src) pairs into padded (B, E) edge lists and runs eqs. 6-7 with
# segment reductions — identical math on the real edges (the dense path's
# masked pairs contribute exact zeros), at E/N^2 of the pair work.

def sweep_sparse_totals(params: Dict, flat: Dict, edge_dst: jax.Array,
                        edge_src: jax.Array, edge_valid: jax.Array,
                        levels: int = MAX_LEVELS) -> jax.Array:
    """Total predicted runtime per graph of a flat stacked batch, sparse.

    ``flat`` holds (B, N, ...) graph arrays (``adj`` unused); ``edge_dst`` /
    ``edge_src`` / ``edge_valid`` are (B, E) padded edge lists (j -> i edges
    as (dst=i, src=j)).  Returns (B,) totals equal (up to float summation
    order) to ``forward_stacked(...)["total_runtime"]`` on the same graphs.
    """
    b, n = flat["mask"].shape
    a_vec = scaleout_vec(flat["a_raw"])
    z_vec = scaleout_vec(flat["z_raw"])
    x = jnp.concatenate([a_vec, flat["context"], z_vec], axis=-1)
    bi = jnp.arange(b)[:, None]
    # Scatter-free edge->node reduction: XLA CPU lowers segment ops to
    # serial scatters, so edge->node sums/maxes run as one-hot
    # broadcast-multiply-sums over the (small) padded edge axis instead;
    # node->edge reads stay row gathers.
    oh_dst = (edge_dst[..., None] == jnp.arange(n)) & edge_valid[..., None]
    oh_dst_f = jnp.where(oh_dst, 1.0, 0.0)               # (B, E, N)

    # eq.6 on real edges only: masked softmax over each node's predecessors
    xe = jnp.concatenate([x[bi, edge_dst], x[bi, edge_src]], axis=-1)
    h3 = _mlp(params["f3"], xe)                          # (B, E, EDGE_DIM)
    logits = jnp.einsum("bef,f->be", jax.nn.leaky_relu(h3, 0.1),
                        params["attn_a"])
    lmax = jnp.max(jnp.where(oh_dst, logits[..., None], -jnp.inf),
                   axis=1)                               # (B, N)
    lmax = jnp.where(jnp.isfinite(lmax), lmax, 0.0)      # no-pred nodes
    lm_e = jnp.take_along_axis(lmax, edge_dst, axis=1)
    w = jnp.where(edge_valid, jnp.exp(logits - lm_e), 0.0)
    den = (oh_dst_f * w[..., None]).sum(axis=1)          # (B, N)
    den_e = jnp.take_along_axis(den, edge_dst, axis=1)
    e = w / jnp.where(den_e > 0, den_e, 1.0)

    # eq.7 level-synchronous propagation via per-edge messages
    w0, b0 = params["f4"][0]["w"], params["f4"][0]["b"]
    pre_h = h3 @ w0[:EDGE_DIM]                           # (B, E, HIDDEN)
    w_m = w0[EDGE_DIM:]
    f4_tail = params["f4"][1:]
    m_obs, valid = flat["metrics"], flat["metrics_valid"]

    def level_step(_, m_cur):
        mj = jnp.where(valid[..., None], m_obs, m_cur)   # (B, N, M)
        hidden = jax.nn.leaky_relu(pre_h + mj[bi, edge_src] @ w_m + b0, 0.1)
        msg = _mlp(f4_tail, hidden)                      # (B, E, M)
        m_prop = (oh_dst_f[..., None] *
                  (e[..., None] * msg)[:, :, None, :]).sum(axis=1)
        return jnp.where(valid[..., None], m_obs, m_prop)

    m_hat = jax.lax.fori_loop(0, levels, level_step, m_obs)

    # eqs. 3-5 readout (per node; eq.5 max-over-predecessors via segment_max)
    m_used = jnp.where(valid[..., None], m_obs, m_hat)
    f1_in = jnp.concatenate([flat["context"], m_used, a_vec, z_vec,
                             flat["r"][..., None]], axis=-1)
    o_hat = _mlp(params["f1"], f1_in)[..., 0]
    f2_in = jnp.concatenate([flat["context"], m_used, z_vec,
                             o_hat[..., None]], axis=-1)
    t_hat = jax.nn.softplus(_mlp(params["f2"], f2_in)[..., 0])

    real_node = flat["mask"] & ~flat["is_summary"]
    t_node = jnp.where(real_node, t_hat, 0.0)
    oh_real = oh_dst & ~flat["is_summary"][bi, edge_src, None]

    def acc_step(_, tt):
        best = jnp.max(jnp.where(oh_real, tt[bi, edge_src, None], 0.0),
                       axis=1)                           # no-pred nodes -> 0
        return t_node + best

    tt_hat = jax.lax.fori_loop(0, levels, acc_step, t_node)
    return jnp.max(jnp.where(real_node, tt_hat, 0.0), axis=-1)


# ------------------------------------------------------------ on-device pick
def pick_candidate(candidates: jax.Array, cand_valid: jax.Array,
                   totals: jax.Array, target: jax.Array) -> jax.Array:
    """Device-side :meth:`EnelScaler._pick`: index of the smallest compliant
    candidate scale-out, else the least-violating one.  ``candidates`` must
    be ascending over the valid entries (argmin then matches the host pick's
    first-of-min tie-breaking).

    Guardrail: non-finite totals (a poisoned model) are treated as +inf so
    they can neither look compliant (NaN <= target is False anyway) nor win
    the least-violating argmin; callers still detect the condition via
    :func:`sweep_totals_ok` and route to the fallback policy."""
    totals = jnp.where(jnp.isfinite(totals), totals, jnp.inf)
    feasible = cand_valid & (totals <= target)
    idx_feasible = jnp.argmin(jnp.where(feasible, candidates, jnp.inf))
    idx_min = jnp.argmin(jnp.where(cand_valid, totals, jnp.inf))
    return jnp.where(feasible.any(), idx_feasible, idx_min)


def sweep_totals_ok(totals: jax.Array, cand_valid: jax.Array) -> jax.Array:
    """Divergence guardrail over one sweep's per-candidate totals: True iff
    every VALID candidate's predicted total is finite.  Computed on device
    and fetched alongside the pick (one transfer, no extra dispatch); a
    False row routes that request to the model-free fallback policy."""
    return jnp.all(jnp.where(cand_valid, jnp.isfinite(totals), True),
                   axis=-1)
