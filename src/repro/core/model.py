"""Enel's graph-propagation prediction model (paper §III-D, eqs. 3-7).

Four 2-layer MLPs (f1..f4) + a GATv2-style attention vector define a spatial
GNN over padded component DAGs:

  eq.6  |e_ij| = softmax_j( a^T sigma( f3(x_i, x_j) ) ),  x = a_vec‖c‖z_vec
  eq.7  m_hat_i = sum_j |e_ij| * f4( f3(x_i,x_j), m_j )   (metric propagation)
  eq.3  o_hat_i = f1(c_i, m_i, a_vec_i, z_vec_i, r_i)     (rescale overhead)
  eq.4  t_hat_i = f2(c_i, m_i, z_vec_i, o_hat_i)          (node runtime)
  eq.5  tt_hat_i = t_hat_i + max_{j in N(i)} tt_hat_j     (critical path)

Metric propagation runs level-synchronously (fori over MAX_NODES levels) so
predictions flow to nodes whose real metrics are unobserved (future
iterations), exactly the paper's online-inference mode.  ~5k parameters —
"allows for training even using a CPU" (§IV-C).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import CTX_DIM, MAX_NODES, N_METRICS

HIDDEN = 32
EDGE_DIM = 16
X_DIM = 3 + CTX_DIM + 3          # a_vec ‖ c ‖ z_vec
MAX_LEVELS = 8                   # longest DAG chain the propagation supports


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (i, o), jnp.float32) / jnp.sqrt(i),
             "b": jnp.zeros(o, jnp.float32)}
            for k, i, o in zip(ks, dims[:-1], dims[1:])]


def _mlp(layers, x, final_linear=True):
    for li, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if li < len(layers) - 1 or not final_linear:
            x = jax.nn.leaky_relu(x, 0.1)
    return x


def init_enel(key) -> Dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        # eq.3: f1(c, m, a_vec, z_vec, r) -> overhead
        "f1": _mlp_init(k1, [CTX_DIM + N_METRICS + 3 + 3 + 1, HIDDEN, 1]),
        # eq.4: f2(c, m, z_vec, o_hat) -> runtime
        "f2": _mlp_init(k2, [CTX_DIM + N_METRICS + 3 + 1, HIDDEN, 1]),
        # eq.6: f3(x_i, x_j) -> edge hidden
        "f3": _mlp_init(k3, [2 * X_DIM, HIDDEN, EDGE_DIM]),
        # eq.7: f4(edge hidden, m_j) -> propagated metrics
        "f4": _mlp_init(k4, [EDGE_DIM + N_METRICS, HIDDEN, N_METRICS]),
        "attn_a": jax.random.normal(k5, (EDGE_DIM,), jnp.float32) / 4.0,
    }


def n_params(params: Dict) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params))


def scaleout_vec(s: jax.Array) -> jax.Array:
    s = jnp.maximum(s, 1e-6)
    return jnp.stack([1.0 - 1.0 / s, jnp.log(s), s], axis=-1)


def _edge_hidden(params, x):
    """f3 on all (i, j) pairs -> (N, N, EDGE_DIM); i = dst, j = src."""
    n = x.shape[0]
    xi = jnp.broadcast_to(x[:, None, :], (n, n, x.shape[-1]))
    xj = jnp.broadcast_to(x[None, :, :], (n, n, x.shape[-1]))
    return _mlp(params["f3"], jnp.concatenate([xi, xj], axis=-1))


def edge_weights(params, x, adj) -> Tuple[jax.Array, jax.Array]:
    """eq.6: masked softmax over predecessors. Returns (e (N,N), h3 (N,N,E))."""
    h3 = _edge_hidden(params, x)
    logits = jnp.einsum("ije,e->ij", jax.nn.leaky_relu(h3, 0.1),
                        params["attn_a"])
    logits = jnp.where(adj, logits, -1e30)
    has_pred = adj.any(axis=1, keepdims=True)
    e = jax.nn.softmax(logits, axis=1)
    return jnp.where(has_pred, e, 0.0), h3


def forward(params: Dict, g: Dict) -> Dict[str, jax.Array]:
    """Full propagation over one padded graph (dict of (N,...) arrays).

    Returns overhead/runtime/accumulated-runtime/propagated-metric predictions.
    """
    a_vec = scaleout_vec(g["a_raw"])
    z_vec = scaleout_vec(g["z_raw"])
    x = jnp.concatenate([a_vec, g["context"], z_vec], axis=-1)
    adj = g["adj"] & g["mask"][None, :] & g["mask"][:, None]
    e, h3 = edge_weights(params, x, adj)

    # eq.7 metric propagation, level-synchronous: observed metrics are fixed
    # inputs; unobserved nodes adopt propagated estimates as they stabilize.
    m_obs = g["metrics"]
    valid = g["metrics_valid"]

    def level_step(_, m_cur):
        mj = jnp.where(valid[:, None], m_obs, m_cur)            # (N, M)
        f4_in = jnp.concatenate(
            [h3, jnp.broadcast_to(mj[None, :, :], h3.shape[:2] + (N_METRICS,))],
            axis=-1)
        msg = _mlp(params["f4"], f4_in)                          # (N,N,M)
        m_prop = jnp.einsum("ij,ijm->im", e, msg)
        return jnp.where(valid[:, None], m_obs, m_prop)

    m_hat = jax.lax.fori_loop(0, MAX_LEVELS, level_step, m_obs)
    m_used = jnp.where(valid[:, None], m_obs, m_hat)

    # eq.3 overhead
    f1_in = jnp.concatenate([g["context"], m_used, a_vec, z_vec,
                             g["r"][:, None]], axis=-1)
    o_hat = _mlp(params["f1"], f1_in)[:, 0]

    # eq.4 runtime (end scale-out only + predicted overhead)
    f2_in = jnp.concatenate([g["context"], m_used, z_vec,
                             o_hat[:, None]], axis=-1)
    t_hat = jax.nn.softplus(_mlp(params["f2"], f2_in)[:, 0])

    # eq.5 accumulated runtime over the DAG (summary nodes excluded)
    t_node = jnp.where(g["mask"] & ~g["is_summary"], t_hat, 0.0)
    real_edge = adj & ~g["is_summary"][None, :]       # drop summary precedents

    def acc_step(_, tt):
        pred_best = jnp.max(
            jnp.where(real_edge, tt[None, :], 0.0), axis=1)
        return t_node + pred_best

    tt_hat = jax.lax.fori_loop(0, MAX_LEVELS, acc_step, t_node)
    tt_hat = jnp.where(g["mask"] & ~g["is_summary"], tt_hat, 0.0)

    return {"overhead": o_hat, "runtime": t_hat, "acc_runtime": tt_hat,
            "metrics": m_hat, "edges": e,
            "total_runtime": jnp.max(tt_hat)}


forward_batch = jax.vmap(forward, in_axes=(None, 0))


def predict_total_runtime(params: Dict, graphs: Dict) -> jax.Array:
    """Total predicted runtime per component graph in a stacked batch."""
    return forward_batch(params, graphs)["total_runtime"]
