# Enel: context-aware dynamic scaling via graph propagation (the paper's
# primary contribution), implemented in pure JAX.
from repro.core.autoencoder import (embed_properties, encode,
                                    init_autoencoder, train_autoencoder)
from repro.core.bell import BellModel, initial_scaleout
from repro.core.ellis import EllisScaler
from repro.core.encoding import binarizer, encode_properties, encode_property, hasher
from repro.core.graph import (ComponentGraph, NodeAttrs, TrainingCache,
                              build_graph, historical_summary, stack_graphs,
                              summary_node)
from repro.core.model import forward, forward_batch, init_enel, n_params
from repro.core.scaling import EnelScaler
from repro.core.service import (DecisionRequest, DecisionResult,
                                DecisionService)
from repro.core.training import EnelTrainer, enel_loss

__all__ = [
    "BellModel", "ComponentGraph", "DecisionRequest", "DecisionResult",
    "DecisionService", "EllisScaler", "EnelScaler", "EnelTrainer",
    "NodeAttrs", "TrainingCache", "binarizer", "build_graph",
    "embed_properties",
    "encode", "encode_properties", "encode_property", "enel_loss", "forward",
    "forward_batch", "hasher", "historical_summary", "init_autoencoder",
    "init_enel", "initial_scaleout", "n_params", "stack_graphs",
    "summary_node", "train_autoencoder",
]
