"""Enel's dynamic scale-out optimizer (paper §IV-A).

Upon each request: fine-tune the global model with the freshest run data
(handled by EnelTrainer), construct the *remaining* component graphs from
static component characteristics (a graph_builder supplied by the job layer),
attach P/H summary nodes, run propagation for EVERY candidate scale-out in
the valid range, and pick the configuration that best complies with the
runtime target (smallest scale-out among the feasible; else the argmin).

The default :meth:`EnelScaler.recommend` is the *batched candidate-sweep*
engine: the graph builder is probed twice per remaining component to derive
ONE candidate-invariant template (context, metrics, adjacency, masks) plus
per-candidate delta arrays (a_raw, z_raw, r, H-summary attributes), and the
full candidate axis is evaluated inside a single jit
(:func:`repro.core.model.sweep_per_component`).  The original
per-candidate-graph implementation is kept as :meth:`recommend_pergraph`
for benchmarking and as a numerical reference.

Builder contract for the batched path: ``a``/``z`` may flow *unchanged* into
node start/end scale-outs (identity only — derived values like (a+z)/2 keep
the template's base value), and time fractions may depend on ``a``/``z``
only through the predicate ``a == z``.  The builder must also be
*structurally deterministic*: for a fixed (component index, predecessor
count) the node count, edge wiring, a/z slot wiring and time-fraction
pattern may not change between calls (node attributes like contexts may) —
the probe that discovers the wiring runs once per key and is cached.  Node contexts are treated as
candidate-invariant: the template is built once at the current scale-out, so
a builder that derives context from ``z`` (e.g. task counts) is evaluated
with the current-scale-out context for every candidate — a deliberate
modeling choice of this engine; use :meth:`EnelScaler.recommend_pergraph`
when exact per-candidate contexts are required.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict, defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.bell import BellModel, initial_scaleout
from repro.core.fallback import FallbackPolicy
from repro.core.graph import (CTX_DIM, N_METRICS, ComponentGraph, NodeAttrs,
                              SWEEP_KEYS, SweepTemplate, bucket_sweep,
                              historical_summaries_batch, historical_summary,
                              propagation_depth, summary_node, sweep_edge_list)
from repro.core.model import pick_candidate
from repro.core.service import DecisionRequest, DecisionResult
from repro.core.training import EnelTrainer

# graph_builder(comp_idx, a, z, predecessors) -> ComponentGraph with
# unobserved metrics/runtimes; predecessors = list of summary NodeAttrs.
GraphBuilder = Callable[[int, float, float, List[NodeAttrs]], ComponentGraph]

# Probe scale-outs used to classify which node slots track the builder's
# a/z arguments.  Exactly representable in float32 and far outside any real
# scale-out range, so equality against the built arrays is unambiguous.
A_PROBE = 1.0e5
Z_PROBE = 2.0e5
H_SLOT = "__H__"          # placeholder name marking the H-summary node slot


class _TemplateDeviceCache:
    """Device-resident sweep-template reuse ACROSS decision points.

    The template base arrays (K, N, ...) are candidate-invariant and change
    little between decision points with the same remaining-component count
    (across runs they are often identical): only the entries derived from
    the current scale-out or the latest summaries move.  One device copy is
    kept per (remaining components, node slots, candidate count) key, and a
    per-key host diff re-ships ONLY the arrays whose values changed — the
    small per-candidate deltas are still rebuilt and shipped every decision
    (they are donated to the sweep jit off-CPU, so they must be fresh).

    The cache is a bounded LRU over keys (default 8 slots) so a long
    multi-job campaign cannot grow device memory without limit; with shape
    bucketing a whole campaign visits only a handful of keys anyway.
    """

    _ids = itertools.count()        # obs label allocator

    def __init__(self, max_slots: int = 8):
        self.max_slots = max_slots
        self._slots: "OrderedDict[Tuple[int, int, int], Tuple[Dict, Dict]]" \
            = OrderedDict()
        # upload/skip/eviction counters: registry-backed behind the
        # original attribute API (properties installed below)
        reg = obs.registry()
        name = f"tc{next(self._ids)}"
        self._obs_counters = {
            "transfers": reg.counter("enel_template_cache_transfers_total",
                                     "device uploads performed"),
            "skips": reg.counter("enel_template_cache_skips_total",
                                 "uploads avoided by the host diff"),
            "evictions": reg.counter("enel_template_cache_evictions_total",
                                     "LRU slots dropped"),
        }
        self._obs_counters = {k: v.labels(cache=name)
                              for k, v in self._obs_counters.items()}

    def adopt(self, template: SweepTemplate, n_candidates: int
              ) -> SweepTemplate:
        """Return ``template`` with ``base``/``h_onehot`` swapped for cached
        device arrays (uploading only what changed since last decision)."""
        k, n = template.base["mask"].shape
        key = (k, n, n_candidates)
        host_new = dict(template.base, __h_onehot__=template.h_onehot)
        slot = self._slots.get(key)
        if slot is None:
            dev = {kk: jnp.asarray(v) for kk, v in host_new.items()}
            self._slots[key] = ({kk: v.copy() for kk, v in host_new.items()},
                                dev)
            self.transfers += len(host_new)
            while len(self._slots) > self.max_slots:
                self._slots.popitem(last=False)
                self.evictions += 1
        else:
            self._slots.move_to_end(key)
            host, dev = slot
            for kk, v in host_new.items():
                if np.array_equal(host[kk], v):
                    self.skips += 1
                    continue
                dev[kk] = jnp.asarray(v)
                host[kk] = v.copy()
                self.transfers += 1
        _, dev = self._slots[key]
        return dataclasses.replace(
            template, base={kk: dev[kk] for kk in template.base},
            h_onehot=dev["__h_onehot__"])


def _install_cache_counter_properties():
    def make(attr):
        def fget(self):
            return int(self._obs_counters[attr].value)

        def fset(self, value):
            self._obs_counters[attr].set(value)
        return property(fget, fset)

    for attr in ("transfers", "skips", "evictions"):
        setattr(_TemplateDeviceCache, attr, make(attr))


_install_cache_counter_properties()


# one device-side reduction + compliant pick over the sweep output; the
# host then fetches (picked index, per-candidate totals) in a single
# transfer instead of one float() sync per candidate
def _totals_pick_impl(per_comp, cand, cand_valid, elapsed, target):
    totals = per_comp.sum(axis=1) + elapsed
    return pick_candidate(cand, cand_valid, totals, target), totals


_totals_pick = jax.jit(_totals_pick_impl)


class EnelScaler:
    def __init__(self, trainer: EnelTrainer, scaleout_range: Tuple[int, int],
                 beta: int = 3, candidate_stride: int = 1):
        self.trainer = trainer
        self.range = scaleout_range
        self.beta = beta
        self.candidate_stride = max(1, candidate_stride)
        # historical summary nodes per component index (across runs)
        self.hist_summaries: Dict[int, List[NodeAttrs]] = defaultdict(list)
        # first-component (scaleout, runtime) pairs for Bell initial alloc
        self.first_component_history: List[Tuple[float, float]] = []
        # last sweep diagnostics: candidates list + (C, K) per-component preds
        # (held as a DecisionResult — device-resident, transferred lazily)
        self.last_candidates: List[int] = []
        self._last_result: Optional[DecisionResult] = None
        # device-resident template arrays reused across decision points
        self.template_cache = _TemplateDeviceCache()
        # guardrail backstop for the single-job recommend() path (the fleet
        # service carries its own policy): non-finite sweep totals never
        # reach a pick — the bounded model-free clamp answers instead
        self.fallback = FallbackPolicy()
        self.fallback_decisions = 0
        # probe-derived structural masks per (comp idx, #predecessors): the
        # A/Z probe only reveals which node slots track the builder's a/z
        # arguments and the a != z time fractions — structural facts that a
        # builder (already bound to the identity-only contract above) keeps
        # fixed per component, so one probe per key serves the whole campaign
        self._probe_cache: Dict[Tuple[int, int], Tuple] = {}
        # identity-stable request constants (edge lists, candidate arrays):
        # reusing the SAME ndarray objects across decisions lets the service
        # skip re-stacking them when nothing changed
        self._edge_cache: Dict[Tuple[int, int, int], Tuple] = {}
        self._cand_cache: Dict[Tuple, Tuple[np.ndarray, np.ndarray]] = {}

    @property
    def last_per_component(self) -> Optional[np.ndarray]:
        """(C, K) per-component predictions of the last sweep (lazy fetch)."""
        if self._last_result is None:
            return None
        return self._last_result.per_component

    def _note_sweep(self, candidates: Sequence[int],
                    result: DecisionResult) -> None:
        self.last_candidates = list(candidates)
        self._last_result = result

    # --------------------------------------------------------------- history
    def record_component(self, comp_idx: int, nodes: Sequence[NodeAttrs],
                         runtime: float) -> None:
        self.hist_summaries[comp_idx].append(
            summary_node(nodes, name=f"P{comp_idx}"))
        if comp_idx == 0:
            scaleout = nodes[-1].end_scaleout
            self.first_component_history.append((scaleout, runtime))

    # ------------------------------------------------------------ initial alloc
    def initial_allocation(self, target_runtime: float,
                           n_components: int) -> int:
        """Bell on the first component + Enel on the rest (paper §IV-A)."""
        if len(self.first_component_history) < 3:
            return max(self.range[0], (self.range[0] + self.range[1]) // 2)
        lo, hi = self.range
        per_comp_target = target_runtime / max(n_components, 1)
        return initial_scaleout(self.first_component_history,
                                per_comp_target, (lo, hi))

    # ------------------------------------------------------------ candidates
    def candidate_scaleouts(self, current_scaleout: int) -> List[int]:
        lo, hi = self.range
        candidates = sorted(set(range(lo, hi + 1, self.candidate_stride))
                            | {hi, current_scaleout})
        return [s for s in candidates if lo <= s <= hi]

    # ---------------------------------------------------------- sweep builder
    def build_sweep(self, *, graph_builder: GraphBuilder, next_comp: int,
                    n_components: int, current_scaleout: int,
                    candidates: Sequence[int],
                    current_summary: Optional[NodeAttrs] = None
                    ) -> Tuple[SweepTemplate, Dict[str, np.ndarray]]:
        """Probe the builder twice per remaining component and assemble the
        candidate-invariant template plus the per-candidate delta arrays."""
        remaining = list(range(next_comp, n_components))
        cand = np.array(candidates, np.float32)
        n_cand, n_rem = len(candidates), len(remaining)
        s_now = float(current_scaleout)

        base_graphs: List[ComponentGraph] = []
        probes: List[Tuple] = []    # (a==A, a==Z, z==A, z==Z, r) per component
        hists: Dict[int, List[NodeAttrs]] = {}
        for k in remaining:
            preds: List[NodeAttrs] = []
            if k == next_comp and current_summary is not None:
                preds.append(current_summary)        # P of the just-finished comp
            hist = self.hist_summaries.get(k - 1, []) if k > 0 else []
            if hist:
                # placeholder H(k-1) slot; attributes are per-candidate deltas
                preds.append(NodeAttrs(
                    name=H_SLOT, context=np.zeros(CTX_DIM, np.float32),
                    metrics=np.zeros(N_METRICS, np.float32),
                    start_scaleout=1.0, end_scaleout=1.0, is_summary=True))
                hists[k] = hist
            base_graphs.append(graph_builder(k, s_now, s_now, list(preds)))
            probe_key = (k, len(preds))
            probe = self._probe_cache.get(probe_key)
            if probe is None:
                pg = graph_builder(k, A_PROBE, Z_PROBE, list(preds))
                probe = (pg.a_raw == A_PROBE, pg.a_raw == Z_PROBE,
                         pg.z_raw == A_PROBE, pg.z_raw == Z_PROBE,
                         pg.r.copy())
                self._probe_cache[probe_key] = probe
            probes.append(probe)

        base = {key: np.stack([getattr(g, key) for g in base_graphs])
                for key in SWEEP_KEYS}
        max_nodes = base["mask"].shape[1]
        h_onehot = np.zeros((n_rem, max_nodes), np.float32)
        for ki, g in enumerate(base_graphs):
            if remaining[ki] in hists:
                if H_SLOT in g.names:
                    h_onehot[ki, g.names.index(H_SLOT)] = 1.0
                else:                    # builder dropped the pred: no H delta
                    del hists[remaining[ki]]
        template = SweepTemplate(
            base=base, h_onehot=h_onehot,
            a_follows_a=np.stack([p[0] for p in probes]),
            a_follows_z=np.stack([p[1] for p in probes]),
            z_follows_a=np.stack([p[2] for p in probes]),
            z_follows_z=np.stack([p[3] for p in probes]),
            r_eq=base["r"].copy(),
            r_neq=np.stack([p[4] for p in probes]),
            comp_ids=remaining,
            levels=max(propagation_depth(g.adj, g.mask)
                       for g in base_graphs) or 1)

        # per-candidate builder arguments (paper: the component about to start
        # rescales from the current allocation; later ones run at z == s)
        z_sel = np.broadcast_to(cand[:, None], (n_cand, n_rem))    # (C, K)
        a_sel = np.where(np.array(remaining)[None, :] == next_comp,
                         s_now, z_sel)
        a3, z3 = a_sel[:, :, None], z_sel[:, :, None]
        a_raw = np.where(template.a_follows_a[None], a3,
                         np.where(template.a_follows_z[None], z3,
                                  base["a_raw"][None]))
        z_raw = np.where(template.z_follows_a[None], a3,
                         np.where(template.z_follows_z[None], z3,
                                  base["z_raw"][None]))
        r = np.where((a_sel == z_sel)[:, :, None],
                     template.r_eq[None], template.r_neq[None])
        metrics_valid = np.broadcast_to(
            base["metrics_valid"][None], (n_cand, n_rem, max_nodes)).copy()
        h_context = np.zeros((n_cand, n_rem, CTX_DIM), np.float32)
        h_metrics = np.zeros((n_cand, n_rem, N_METRICS), np.float32)
        for ki, k in enumerate(remaining):
            if k not in hists:
                continue
            h = historical_summaries_batch(hists[k], cand, beta=self.beta)
            slot = int(np.argmax(h_onehot[ki]))
            h_context[:, ki] = h["context"]
            h_metrics[:, ki] = h["metrics"]
            metrics_valid[:, ki, slot] = h["metrics_valid"]
            a_raw[:, ki, slot] = np.maximum(h["start"], 1e-6)
            z_raw[:, ki, slot] = np.maximum(h["end"], 1e-6)
        deltas = {"a_raw": a_raw.astype(np.float32),
                  "z_raw": z_raw.astype(np.float32),
                  "r": r.astype(np.float32),
                  "metrics_valid": metrics_valid,
                  "h_context": h_context, "h_metrics": h_metrics}
        return template, deltas

    # ------------------------------------------------------------- recommend
    def recommend(self, *, graph_builder: GraphBuilder, next_comp: int,
                  n_components: int, elapsed: float, current_scaleout: int,
                  target_runtime: float,
                  current_summary: Optional[NodeAttrs] = None
                  ) -> Tuple[int, float, Dict[int, float]]:
        """Batched sweep: returns (scaleout, predicted_total, per-cand totals)."""
        candidates = self.candidate_scaleouts(current_scaleout)
        if next_comp >= n_components:
            return current_scaleout, elapsed, {}
        template, deltas = self.build_sweep(
            graph_builder=graph_builder, next_comp=next_comp,
            n_components=n_components, current_scaleout=current_scaleout,
            candidates=candidates, current_summary=current_summary)
        template = self.template_cache.adopt(template, len(candidates))
        per_dev = self.trainer.predict_sweep_device(template, deltas)  # (C, K)
        cand_arr = np.array(candidates, np.float32)
        idx_dev, totals_dev = _totals_pick(
            per_dev, cand_arr, np.ones(len(candidates), bool),
            np.float32(elapsed), np.float32(target_runtime))
        # single host transfer: the pick + the per-candidate totals
        idx, totals_np = jax.device_get((idx_dev, totals_dev))
        if not np.isfinite(totals_np).all():    # guardrail: poisoned model
            self.fallback_decisions += 1
            best, pred = self.fallback.decide(
                candidates, totals_np, current_scaleout, elapsed,
                target_runtime)
            totals = {s: float(t) for s, t in zip(candidates, totals_np)
                      if np.isfinite(t)}
            return best, pred, totals
        totals = {s: float(totals_np[i]) for i, s in enumerate(candidates)}
        best = candidates[int(idx)]
        self._note_sweep(candidates, DecisionResult(
            scaleout=best, predicted=totals[best], totals=totals,
            per_component_dev=per_dev, n_candidates=per_dev.shape[0],
            n_components=per_dev.shape[1]))
        return best, totals[best], totals

    # ------------------------------------------------- fleet decision service
    def prepare_request(self, *, graph_builder: GraphBuilder, next_comp: int,
                        n_components: int, elapsed: float,
                        current_scaleout: int, target_runtime: float,
                        current_summary: Optional[NodeAttrs] = None,
                        best_effort: bool = False
                        ) -> Optional[DecisionRequest]:
        """Build this job's pending decision as a shape-bucketed request for
        :class:`repro.core.service.DecisionService`.

        The sweep is assembled exactly as :meth:`recommend` would, then
        padded to the fixed shape ladders (padded components read out as
        exactly 0 and padded candidates are masked from the pick), the real
        edges are gathered for the sparse engine, and the template base
        arrays are swapped for the device-resident cache copies.  Returns
        ``None`` when there is nothing left to decide.
        """
        candidates = self.candidate_scaleouts(current_scaleout)
        if next_comp >= n_components:
            return None
        template, deltas = self.build_sweep(
            graph_builder=graph_builder, next_comp=next_comp,
            n_components=n_components, current_scaleout=current_scaleout,
            candidates=candidates, current_summary=current_summary)
        template, deltas, (c_real, k_real) = bucket_sweep(template, deltas)
        c_b = deltas["a_raw"].shape[0]
        # keyed by the REAL remaining-component count too: decision points
        # sharing a K rung but differing in real adj/mask must not thrash
        # one slot (identity-stable edges keep the service stack memo warm)
        ekey = (k_real,) + template.base["mask"].shape
        cached = self._edge_cache.get(ekey)
        if cached is not None and \
                np.array_equal(cached[0], template.base["adj"]) and \
                np.array_equal(cached[1], template.base["mask"]):
            edge_dst, edge_src, edge_valid = cached[2]
        else:
            edges = sweep_edge_list(template.base)
            self._edge_cache[ekey] = (template.base["adj"].copy(),
                                      template.base["mask"].copy(), edges)
            edge_dst, edge_src, edge_valid = edges
        template = self.template_cache.adopt(template, c_b)
        ckey = (c_b,) + tuple(candidates)
        if ckey in self._cand_cache:
            cand_arr, cand_valid = self._cand_cache[ckey]
        else:
            cand_arr = np.full(c_b, candidates[-1], np.float32)
            cand_arr[:c_real] = candidates
            cand_valid = np.zeros(c_b, bool)
            cand_valid[:c_real] = True
            self._cand_cache[ckey] = (cand_arr, cand_valid)
        return DecisionRequest(
            params=self.trainer.params, base=template.base,
            h_onehot=template.h_onehot, deltas=deltas, edge_dst=edge_dst,
            edge_src=edge_src, edge_valid=edge_valid, candidates=cand_arr,
            cand_valid=cand_valid, elapsed=float(elapsed),
            target=float(target_runtime), levels=template.levels,
            candidate_list=list(candidates), n_components=k_real,
            current_scaleout=int(current_scaleout),
            best_effort=bool(best_effort))

    def apply_decision(self, request: DecisionRequest,
                       result: DecisionResult
                       ) -> Tuple[int, float, Dict[int, float]]:
        """Record a service decision's diagnostics; returns the same
        (scaleout, predicted_total, totals) triple as :meth:`recommend`."""
        self._note_sweep(request.candidate_list, result)
        return result.scaleout, result.predicted, result.totals

    def recommend_pergraph(self, *, graph_builder: GraphBuilder,
                           next_comp: int, n_components: int, elapsed: float,
                           current_scaleout: int, target_runtime: float,
                           current_summary: Optional[NodeAttrs] = None
                           ) -> Tuple[int, float, Dict[int, float]]:
        """Original per-candidate graph-construction path (reference/bench)."""
        candidates = self.candidate_scaleouts(current_scaleout)
        totals: Dict[int, float] = {}
        remaining_idx = list(range(next_comp, n_components))
        if not remaining_idx:
            return current_scaleout, elapsed, totals

        # one vmapped forward over all (candidate x remaining-component) graphs
        all_graphs: List[ComponentGraph] = []
        for s in candidates:
            for k in remaining_idx:
                # P(k-1)/H(k-1) are predecessors of G(k)'s roots (paper Fig.3)
                preds: List[NodeAttrs] = []
                if k == next_comp and current_summary is not None:
                    preds.append(current_summary)    # P of the just-finished comp
                if k > 0:
                    h = historical_summary(self.hist_summaries.get(k - 1, []),
                                           float(s), beta=self.beta)
                    if h is not None:
                        preds.append(h)
                a = current_scaleout if k == next_comp else s
                all_graphs.append(graph_builder(k, float(a), float(s), preds))
        per_comp = self.trainer.predict(all_graphs).reshape(
            len(candidates), len(remaining_idx))
        for i, s in enumerate(candidates):
            totals[s] = elapsed + float(per_comp[i].sum())
        return self._pick(candidates, totals, target_runtime)

    @staticmethod
    def _pick(candidates: Sequence[int], totals: Dict[int, float],
              target_runtime: float) -> Tuple[int, float, Dict[int, float]]:
        feasible = [s for s in candidates if totals[s] <= target_runtime]
        if feasible:
            best = min(feasible)                 # cheapest compliant scale-out
        else:
            best = min(totals, key=totals.get)   # least violation
        return best, totals[best], totals
