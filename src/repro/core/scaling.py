"""Enel's dynamic scale-out optimizer (paper §IV-A).

Upon each request: fine-tune the global model with the freshest run data
(handled by EnelTrainer), construct the *remaining* component graphs from
static component characteristics (a graph_builder supplied by the job layer),
attach P/H summary nodes, run propagation for EVERY candidate scale-out in
the valid range, and pick the configuration that best complies with the
runtime target (smallest scale-out among the feasible; else the argmin).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bell import BellModel, initial_scaleout
from repro.core.graph import (ComponentGraph, NodeAttrs, historical_summary,
                              summary_node)
from repro.core.training import EnelTrainer

# graph_builder(comp_idx, a, z, predecessors) -> ComponentGraph with
# unobserved metrics/runtimes; predecessors = list of summary NodeAttrs.
GraphBuilder = Callable[[int, float, float, List[NodeAttrs]], ComponentGraph]


class EnelScaler:
    def __init__(self, trainer: EnelTrainer, scaleout_range: Tuple[int, int],
                 beta: int = 3, candidate_stride: int = 1):
        self.trainer = trainer
        self.range = scaleout_range
        self.beta = beta
        self.candidate_stride = max(1, candidate_stride)
        # historical summary nodes per component index (across runs)
        self.hist_summaries: Dict[int, List[NodeAttrs]] = defaultdict(list)
        # first-component (scaleout, runtime) pairs for Bell initial alloc
        self.first_component_history: List[Tuple[float, float]] = []

    # --------------------------------------------------------------- history
    def record_component(self, comp_idx: int, nodes: Sequence[NodeAttrs],
                         runtime: float) -> None:
        self.hist_summaries[comp_idx].append(
            summary_node(nodes, name=f"P{comp_idx}"))
        if comp_idx == 0:
            scaleout = nodes[-1].end_scaleout
            self.first_component_history.append((scaleout, runtime))

    # ------------------------------------------------------------ initial alloc
    def initial_allocation(self, target_runtime: float,
                           n_components: int) -> int:
        """Bell on the first component + Enel on the rest (paper §IV-A)."""
        if len(self.first_component_history) < 3:
            return max(self.range[0], (self.range[0] + self.range[1]) // 2)
        lo, hi = self.range
        per_comp_target = target_runtime / max(n_components, 1)
        return initial_scaleout(self.first_component_history,
                                per_comp_target, (lo, hi))

    # ------------------------------------------------------------- recommend
    def recommend(self, *, graph_builder: GraphBuilder, next_comp: int,
                  n_components: int, elapsed: float, current_scaleout: int,
                  target_runtime: float,
                  current_summary: Optional[NodeAttrs] = None
                  ) -> Tuple[int, float, Dict[int, float]]:
        """Returns (scaleout, predicted_total, per-candidate totals)."""
        lo, hi = self.range
        candidates = sorted(set(range(lo, hi + 1, self.candidate_stride))
                            | {hi, current_scaleout})
        candidates = [s for s in candidates if lo <= s <= hi]
        totals: Dict[int, float] = {}
        remaining_idx = list(range(next_comp, n_components))
        if not remaining_idx:
            return current_scaleout, elapsed, totals

        # one vmapped forward over all (candidate x remaining-component) graphs
        all_graphs: List[ComponentGraph] = []
        for s in candidates:
            for k in remaining_idx:
                # P(k-1)/H(k-1) are predecessors of G(k)'s roots (paper Fig.3)
                preds: List[NodeAttrs] = []
                if k == next_comp and current_summary is not None:
                    preds.append(current_summary)        # P of the just-finished comp
                if k > 0:
                    h = historical_summary(self.hist_summaries.get(k - 1, []),
                                           float(s), beta=self.beta)
                    if h is not None:
                        preds.append(h)
                a = current_scaleout if k == next_comp else s
                all_graphs.append(graph_builder(k, float(a), float(s), preds))
        per_comp = self.trainer.predict(all_graphs).reshape(
            len(candidates), len(remaining_idx))
        for i, s in enumerate(candidates):
            totals[s] = elapsed + float(per_comp[i].sum())

        feasible = [s for s in candidates if totals[s] <= target_runtime]
        if feasible:
            best = min(feasible)                 # cheapest compliant scale-out
        else:
            best = min(totals, key=totals.get)   # least violation
        return best, totals[best], totals
