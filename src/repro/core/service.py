"""Fleet-scale decision service: shape-bucketed, cross-job batched sweeps.

One rescaling decision is a (template, deltas) candidate sweep (see
``core/scaling.py``).  This module turns decisions into a batched,
recompilation-free service:

* every request arrives padded to the fixed shape ladders of
  :func:`repro.core.graph.bucket_sweep`, so the whole fleet shares a handful
  of jit shapes instead of one per exact sweep;
* requests with the same bucket key are stacked along a new job axis J
  (per-request model parameters included — each tenant keeps its own model)
  and evaluated in ONE jit dispatch, vmapped over the existing sweep
  assembly + the sparse-edge engine (:func:`~repro.core.model.sweep_sparse_totals`);
* the compliant-scale-out pick runs on device
  (:func:`~repro.core.model.pick_candidate`); the host fetches the picked
  indices and per-candidate totals in a single transfer, and the (J, C, K)
  per-component diagnostics stay on device until someone asks.

Fault tolerance (the control plane assumes the model CAN fail):

* a per-row on-device ``isfinite`` reduce
  (:func:`~repro.core.model.sweep_totals_ok`) rides the existing pick
  transfer; rows whose valid totals are non-finite are answered by the
  bounded model-free :class:`~repro.core.fallback.FallbackPolicy` instead
  of a poisoned pick;
* dispatch is wrapped in a retry envelope — capped exponential backoff with
  seeded jitter under a per-call deadline — and a :class:`CircuitBreaker`
  that trips the whole service into fallback mode after K consecutive
  failed dispatches, then half-opens on a probe cadence;
* overload shedding (the first piece of ROADMAP item 2's admission
  control): above ``shed_capacity`` pending requests per call, excess
  requests — best-effort ones first — are rejected to the fallback policy
  without touching the dispatch path.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, defaultdict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.fallback import FallbackPolicy
from repro.core.graph import ladder_bucket
from repro.core.model import (assemble_sweep_batch, pick_candidate,
                              record_trace, sweep_sparse_totals,
                              sweep_totals_ok)

JOB_LADDER = (1, 2, 4, 8, 16, 32)       # job axis J (pad by repeating a row)

# service robustness counters: attribute name -> (metric family, help).
# Registered in the unified obs registry, exposed behind the original
# attribute API via properties (see _install_counter_properties below).
_SERVICE_COUNTERS = {
    "decisions": ("enel_service_decisions_total", "requests served"),
    "dispatches": ("enel_service_dispatches_total", "jit dispatches issued"),
    "batched_away": ("enel_service_batched_away_total",
                     "dispatches saved vs one-per-request"),
    "fallback_decisions": ("enel_service_fallback_decisions_total",
                           "requests answered by the fallback policy"),
    "guardrail_trips": ("enel_service_guardrail_trips_total",
                        "non-finite sweep rows caught by the guardrail"),
    "retries": ("enel_service_retries_total",
                "dispatch attempts beyond the first"),
    "dispatch_failures": ("enel_service_dispatch_failures_total",
                          "failed dispatch attempts (incl. retried)"),
    "shed_requests": ("enel_service_shed_requests_total",
                      "requests rejected under overload"),
}

_BREAKER_STATE_CODE = {"closed": 0, "half_open": 1, "open": 2}


class DispatchFault(RuntimeError):
    """A decision dispatch failed (retryable)."""


class DispatchTimeout(DispatchFault):
    """A decision dispatch exceeded its deadline (chaos injection raises
    this; a real deployment would raise it from an RPC timer)."""


def _job_bucket(j: int) -> int:
    return ladder_bucket(j, JOB_LADDER)


def _stack_leaves(*xs):
    """Host leaves: one np.stack + one upload; device leaves: jnp.stack."""
    if isinstance(xs[0], np.ndarray):
        return jnp.asarray(np.stack(xs))
    return jnp.stack(xs)


@dataclasses.dataclass
class DecisionRequest:
    """One job's pending rescaling decision, already shape-bucketed.

    ``base``/``h_onehot`` may be device arrays (the scaler's template cache
    keeps them resident across decision points); ``deltas`` and the edge
    lists are fresh host arrays every decision.

    ``current_scaleout`` carries the requester's live allocation so a
    fallback answer can step FROM somewhere; ``best_effort`` marks requests
    the service may shed first under overload.
    """
    params: Dict                      # this tenant's model parameters
    base: Dict                        # (K, N, ...) template arrays
    h_onehot: np.ndarray              # (K, N)
    deltas: Dict[str, np.ndarray]     # (C, K, ...)
    edge_dst: np.ndarray              # (K, E) int32
    edge_src: np.ndarray              # (K, E) int32
    edge_valid: np.ndarray            # (K, E) bool
    candidates: np.ndarray            # (C,) float32, padded ascending
    cand_valid: np.ndarray            # (C,) bool
    elapsed: float
    target: float
    levels: int
    candidate_list: List[int]         # the real candidate scale-outs
    n_components: int                 # real K (pre-padding)
    current_scaleout: int = 0         # requester's live allocation
    best_effort: bool = False         # sheddable under overload

    @property
    def bucket_key(self):
        k, n = self.h_onehot.shape
        return (len(self.candidates), k, n, self.edge_dst.shape[1],
                self.levels)


class DecisionResult:
    """Pick + totals (fetched in one transfer); per-component preds lazy.

    ``service_seconds`` is this request's amortized share of the service
    call that produced it — the runner bills it to the run's decision
    latency instead of timing across its generator suspension (which,
    under fleet interleaving, would charge one job for the whole round).

    ``fallback``/``shed`` flag decisions the model did not make: answered
    by the heuristic policy (guardrail trip, breaker open, retries
    exhausted) or rejected under overload, respectively.
    """

    def __init__(self, scaleout: int, predicted: float,
                 totals: Dict[int, float], per_component_dev,
                 n_candidates: int, n_components: int):
        self.scaleout = scaleout
        self.predicted = predicted
        self.totals = totals
        self.service_seconds = 0.0
        self.fallback = False
        self.shed = False
        self._per_dev = per_component_dev       # (C_bucket, K_bucket) device
        self._shape = (n_candidates, n_components)
        self._per_np: Optional[np.ndarray] = None

    @property
    def per_component(self) -> np.ndarray:
        """(C, K) per-component predictions; device->host on first access.
        Fallback decisions carry no sweep: their diagnostics read as 0."""
        if self._per_np is None:
            if self._per_dev is None:
                self._per_np = np.zeros(self._shape, np.float32)
            else:
                c, k = self._shape
                self._per_np = np.asarray(self._per_dev)[:c, :k]
        return self._per_np


def sweep_eval_one(p, b, oh, d, ed, es, ev, cd, cv, el, tg, levels):
    """One job's sweep: assemble + sparse totals + on-device compliant pick.

    Returns (pick index, per-candidate totals, (C, K) per-component
    predictions, finite-totals ok flag).  Module-level so the fused campaign
    kernel (``core/campaign_kernel.py``) evaluates decisions with EXACTLY the
    ops the fleet service dispatches — one numerics contract, two drivers.
    """
    c, k = d["a_raw"].shape[:2]
    flat = assemble_sweep_batch(b, oh, d)
    tile = lambda a: jnp.broadcast_to(
        a[None], (c,) + a.shape).reshape((c * k,) + a.shape[1:])
    per = sweep_sparse_totals(p, flat, tile(ed), tile(es), tile(ev),
                              levels).reshape(c, k)
    totals = per.sum(axis=1) + el
    idx = pick_candidate(cd, cv, totals, tg)
    ok = sweep_totals_ok(totals, cv)
    return idx, totals, per, ok


def _fleet_impl(params, base, h_onehot, deltas, edge_dst, edge_src,
                edge_valid, cand, cand_valid, elapsed, target, levels):
    """vmap over the job axis: assemble + sparse sweep + on-device pick.

    Returns per job row (pick index, per-candidate totals, (C, K)
    per-component predictions, finite-totals ok flag).  The ok reduce is
    folded into this dispatch so the guardrail costs no extra dispatch and
    rides the existing pick+totals transfer.
    """
    record_trace("fleet_sweep")

    def one(p, b, oh, d, ed, es, ev, cd, cv, el, tg):
        return sweep_eval_one(p, b, oh, d, ed, es, ev, cd, cv, el, tg,
                              levels)

    return jax.vmap(one)(params, base, h_onehot, deltas, edge_dst, edge_src,
                         edge_valid, cand, cand_valid, elapsed, target)


_fleet_jit = jax.jit(_fleet_impl, static_argnums=(11,))


def apply_capacity(request: DecisionRequest, max_scaleout: int
                   ) -> DecisionRequest:
    """Capacity-capped pick: mask candidates above ``max_scaleout`` (a
    multi-tenant executor-pool constraint) so the on-device compliant pick
    can only choose a scale-out the shrunken pool can actually grant.

    Returns ``request`` unchanged when the cap does not bind.  If the cap
    excludes every candidate, the smallest valid candidate stays eligible
    (a job never picks below the range floor; the pool accounting admits
    jobs only with at least that much headroom).
    """
    over = request.cand_valid & (request.candidates > max_scaleout)
    if not over.any():
        return request
    cv = request.cand_valid & ~over
    if not cv.any():
        lo = request.candidates[request.cand_valid].min()
        cv = request.cand_valid & (request.candidates <= lo)
    return dataclasses.replace(request, cand_valid=cv)


class CircuitBreaker:
    """Dispatch-path circuit breaker: CLOSED -> OPEN after ``threshold``
    consecutive failed dispatch calls; OPEN serves every request from the
    fallback policy; after ``probe_after`` blocked calls the breaker
    HALF-OPENs and lets one probe call through — success closes it,
    failure re-opens (counting another trip)."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 3, probe_after: int = 4,
                 name: str = "breaker"):
        self.threshold = int(threshold)
        self.probe_after = int(probe_after)
        self.name = name
        self.state = self.CLOSED
        self.consecutive_failures = 0
        self._blocked_calls = 0
        self.last_transition_seq = -1   # flight-recorder seq of last flip
        reg = obs.registry()
        self._trips = reg.counter(
            "enel_breaker_trips_total",
            "breaker transitions into OPEN").labels(service=name)
        self._state_gauge = reg.gauge(
            "enel_breaker_state",
            "1 for the current breaker state, 0 otherwise")
        self._sync_state_gauge()

    @property
    def trips(self) -> int:
        return int(self._trips.value)

    @trips.setter
    def trips(self, v: int) -> None:
        self._trips.set(v)

    def _sync_state_gauge(self) -> None:
        for s in (self.CLOSED, self.OPEN, self.HALF_OPEN):
            self._state_gauge.labels(service=self.name, state=s).set(
                1.0 if s == self.state else 0.0)

    def _transition(self, new_state: str, reason: str) -> None:
        if new_state == self.state:
            return
        self.last_transition_seq = obs.emit(
            "breaker.transition", service=self.name,
            src=self.state, dst=new_state, reason=reason,
            trips=self.trips, failures=self.consecutive_failures)
        self.state = new_state
        self._sync_state_gauge()

    def allow(self) -> bool:
        """One call per service decide(): may this call dispatch?"""
        if self.state == self.OPEN:
            self._blocked_calls += 1
            if self._blocked_calls >= self.probe_after:
                self._transition(self.HALF_OPEN, "probe_window")
            return False
        return True                     # closed, or half-open (the probe)

    def record(self, success: bool) -> None:
        if success:
            self.consecutive_failures = 0
            self._transition(self.CLOSED, "dispatch_ok")
            return
        self.consecutive_failures += 1
        if self.state == self.HALF_OPEN or \
                self.consecutive_failures >= self.threshold:
            reason = ("probe_failed" if self.state == self.HALF_OPEN
                      else "failure_threshold")
            self._blocked_calls = 0
            self.trips += 1
            self._transition(self.OPEN, reason)

    def snapshot(self) -> Dict:
        return {"state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "trips": self.trips,
                "blocked_calls": self._blocked_calls,
                "last_transition_seq": self.last_transition_seq}

    def restore(self, st: Dict) -> None:
        self.state = st["state"]
        self.consecutive_failures = st["consecutive_failures"]
        self.trips = st["trips"]
        self._blocked_calls = st["blocked_calls"]
        self.last_transition_seq = st.get("last_transition_seq", -1)
        self._sync_state_gauge()        # registry labels track restored state


class DecisionService:
    """Collects concurrent decision requests and dispatches them batched.

    ``decide`` groups requests by bucket key, pads each group to a JOB_LADDER
    rung along the job axis, evaluates every group in one jit dispatch and
    fetches each group's picks + totals in a single host transfer.

    Dispatch is double-buffered by default: every group is stacked and
    dispatched first (jax dispatch is async), and the host transfers are
    fetched in a second pass — so host request-stacking of the next bucket
    overlaps device compute of the current one.  ``double_buffer=False``
    restores the synchronous stack->dispatch->fetch loop (decision parity
    between the two modes is asserted in tests).

    Failure envelope: each group dispatch retries up to ``max_retries``
    times under capped exponential backoff with seeded jitter, bounded by
    ``deadline_s`` per decide() call; consecutive decide() calls whose
    dispatches fail trip the :class:`CircuitBreaker` into fallback-for-all
    mode.  Rows whose predictions come back non-finite are answered by the
    :class:`~repro.core.fallback.FallbackPolicy` WITHOUT tripping the
    breaker (a poisoned tenant model is a per-row condition, not a service
    outage; its fallback rate is visible in the counters).  ``fault_injector``
    is the chaos hook: a callable invoked once per dispatch attempt that
    may raise :class:`DispatchFault`.
    """

    _ids = itertools.count()        # default obs label allocator

    def __init__(self, double_buffer: bool = True, *,
                 fallback: Optional[FallbackPolicy] = None,
                 max_retries: int = 2, backoff_base_s: float = 0.02,
                 backoff_cap_s: float = 0.25,
                 deadline_s: Optional[float] = None,
                 breaker_threshold: int = 3, breaker_probe_after: int = 4,
                 shed_capacity: Optional[int] = None, seed: int = 0,
                 obs_name: Optional[str] = None):
        self.double_buffer = double_buffer
        self.fallback = fallback or FallbackPolicy()
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.deadline_s = deadline_s
        # obs_name keys this instance's registry series; pass a stable name
        # to make a restored-from-checkpoint service label-identical.
        self.obs_name = obs_name or f"svc{next(self._ids)}"
        reg = obs.registry()
        self._obs_counters = {
            attr: reg.counter(family, help).labels(service=self.obs_name)
            for attr, (family, help) in _SERVICE_COUNTERS.items()}
        self.breaker = CircuitBreaker(breaker_threshold, breaker_probe_after,
                                      name=self.obs_name)
        self.shed_capacity = shed_capacity
        self.fault_injector = None      # chaos hook (see repro.sim.chaos)
        self._rng = np.random.RandomState(seed ^ 0xbac0ff)  # backoff jitter
        # identity-memoized stacks: params / template-base device arrays /
        # edge lists are object-stable across decision rounds (the scalers'
        # caches re-serve the same ndarrays while values are unchanged), so
        # their (J, ...) stacks are reused instead of re-stacked per round.
        # LRU-bounded so a long campaign over many bucket/fleet shapes
        # cannot pin stacked device arrays without limit.
        self._stack_memo: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._stack_memo_slots = 64

    @property
    def breaker_trips(self) -> int:
        return self.breaker.trips

    def _stack_tree(self, cache_key: tuple, rows, get):
        trees = [get(r) for r in rows]
        all_leaves = [jax.tree_util.tree_leaves(t) for t in trees]
        ids = tuple(id(l) for row in all_leaves for l in row)
        hit = self._stack_memo.get(cache_key)
        if hit is not None and hit[0] == ids:
            self._stack_memo.move_to_end(cache_key)
            return hit[2]
        treedef = jax.tree_util.tree_structure(trees[0])
        stacked = jax.tree_util.tree_unflatten(
            treedef, [_stack_leaves(*col) for col in zip(*all_leaves)])
        # keep the leaf refs alive so the memo's ids cannot be recycled
        self._stack_memo[cache_key] = (ids, all_leaves, stacked)
        while len(self._stack_memo) > self._stack_memo_slots:
            self._stack_memo.popitem(last=False)
        return stacked

    def _dispatch_group(self, key: tuple, group: List[DecisionRequest]):
        """Stack one bucket group and issue its (async) jit dispatch."""
        if self.fault_injector is not None:
            self.fault_injector()       # chaos: may raise DispatchFault
        j_b = _job_bucket(len(group))
        rows = group + [group[-1]] * (j_b - len(group))
        stack = lambda get: jax.tree_util.tree_map(
            _stack_leaves, *[get(r) for r in rows])
        out = _fleet_jit(
            self._stack_tree((key, j_b, "params"), rows,
                             lambda r: r.params),
            self._stack_tree((key, j_b, "base"), rows, lambda r: r.base),
            self._stack_tree((key, j_b, "h_onehot"), rows,
                             lambda r: r.h_onehot),
            stack(lambda r: r.deltas),
            self._stack_tree((key, j_b, "edge_dst"), rows,
                             lambda r: r.edge_dst),
            self._stack_tree((key, j_b, "edge_src"), rows,
                             lambda r: r.edge_src),
            self._stack_tree((key, j_b, "edge_valid"), rows,
                             lambda r: r.edge_valid),
            self._stack_tree((key, j_b, "candidates"), rows,
                             lambda r: r.candidates),
            self._stack_tree((key, j_b, "cand_valid"), rows,
                             lambda r: r.cand_valid),
            jnp.asarray([r.elapsed for r in rows], jnp.float32),
            jnp.asarray([r.target for r in rows], jnp.float32),
            group[0].levels)
        self.dispatches += 1
        self.batched_away += len(group) - 1
        return out

    # ------------------------------------------------------ failure envelope
    def _fallback_result(self, req: DecisionRequest,
                         totals_row: Optional[np.ndarray] = None,
                         shed: bool = False, cause: str = "guardrail",
                         cause_seq: int = -1) -> DecisionResult:
        """Answer one request from the bounded heuristic policy.

        ``cause`` names why the model did not answer (shed, breaker_open,
        retries_exhausted, guardrail); ``cause_seq`` links the span to the
        flight-recorder event that forced the fallback."""
        totals = None
        if totals_row is not None:
            totals = {s: float(totals_row[ci])
                      for ci, s in enumerate(req.candidate_list)}
        s, pred = self.fallback.decide(
            req.candidate_list, totals, req.current_scaleout,
            req.elapsed, req.target)
        res = DecisionResult(
            scaleout=int(s), predicted=pred,
            totals=self.fallback._finite_totals(req.candidate_list, totals),
            per_component_dev=None,
            n_candidates=len(req.candidate_list),
            n_components=req.n_components)
        res.fallback = True
        res.shed = shed
        self.fallback_decisions += 1
        if shed:
            self.shed_requests += 1
        obs.emit("decision.fallback", service=self.obs_name, cause=cause,
                 cause_seq=cause_seq, shed=shed, scaleout=int(s),
                 from_scaleout=int(req.current_scaleout))
        return res

    def _dispatch_with_retry(self, key: tuple,
                             group: List[DecisionRequest],
                             t_start: float, deadline: Optional[float]):
        """Dispatch one group under the retry/backoff/deadline envelope;
        returns (jit output or None when the envelope is exhausted,
        retries used, flight-recorder seq of the last fault span)."""
        attempt = 0
        fault_seq = -1
        while True:
            try:
                return self._dispatch_group(key, group), attempt, fault_seq
            except DispatchFault as e:
                self.dispatch_failures += 1
                fault_seq = obs.emit(
                    "dispatch.fault", service=self.obs_name,
                    bucket=str(key), group=len(group), attempt=attempt,
                    fault=type(e).__name__)
                sleep = min(self.backoff_cap_s,
                            self.backoff_base_s * (2 ** attempt))
                sleep *= 0.5 + self._rng.rand()     # seeded jitter
                if attempt >= self.max_retries or (
                        deadline is not None and
                        time.time() - t_start + sleep > deadline):
                    return None, attempt, fault_seq
                time.sleep(sleep)
                self.retries += 1
                attempt += 1

    def _shed(self, requests: Sequence[DecisionRequest],
              results: List[Optional[DecisionResult]]) -> List[int]:
        """Admission control: above ``shed_capacity`` pending requests,
        reject the excess — best-effort requests first, newest first —
        straight to the fallback policy.  Returns the surviving indices."""
        live = list(range(len(requests)))
        if self.shed_capacity is None or len(live) <= self.shed_capacity:
            return live
        excess = len(live) - int(self.shed_capacity)
        order = [i for i in reversed(live) if requests[i].best_effort] + \
                [i for i in reversed(live) if not requests[i].best_effort]
        for i in order[:excess]:
            results[i] = self._fallback_result(requests[i], shed=True)
        return [i for i in live if results[i] is None]

    def decide(self, requests: Sequence[DecisionRequest]
               ) -> List[DecisionResult]:
        t_start = time.time()
        results: List[Optional[DecisionResult]] = [None] * len(requests)
        live = self._shed(requests, results)
        if live and not self.breaker.allow():       # open: fallback for all
            for i in live:
                results[i] = self._fallback_result(
                    requests[i], cause="breaker_open",
                    cause_seq=self.breaker.last_transition_seq)
            live = []
        groups: Dict[tuple, List[int]] = defaultdict(list)
        for i in live:
            groups[requests[i].bucket_key].append(i)
        deadline = self.deadline_s
        staged = []
        dispatch_ok = True
        for key, idxs in groups.items():
            out, retried, fault_seq = self._dispatch_with_retry(
                key, [requests[i] for i in idxs], t_start, deadline)
            if out is None:                         # envelope exhausted
                dispatch_ok = False
                for i in idxs:
                    results[i] = self._fallback_result(
                        requests[i], cause="retries_exhausted",
                        cause_seq=fault_seq)
                continue
            if not self.double_buffer:
                # synchronous mode: fetch before stacking the next bucket
                out = (jax.device_get((out[0], out[1], out[3])), out[2])
            staged.append((idxs, key, retried, out))
        for idxs, key, retried, out in staged:
            if self.double_buffer:
                picked, totals, per, ok = out
                # ONE host transfer per group: picks + totals + ok flags
                picked_np, totals_np, ok_np = jax.device_get(
                    (picked, totals, ok))
            else:
                (picked_np, totals_np, ok_np), per = out
            obs.emit("decision.dispatch", service=self.obs_name,
                     bucket=str(key), group=len(idxs), retries=retried,
                     latency_s=round(time.time() - t_start, 6))
            for gi, ri in enumerate(idxs):
                req = requests[ri]
                if not bool(ok_np[gi]):     # guardrail: poisoned sweep row
                    self.guardrail_trips += 1
                    trip_seq = obs.emit(
                        "guardrail.trip", service=self.obs_name,
                        bucket=str(key), row=gi)
                    results[ri] = self._fallback_result(
                        req, totals_row=totals_np[gi], cause="guardrail",
                        cause_seq=trip_seq)
                    continue
                sl = int(picked_np[gi])
                tot = {s: float(totals_np[gi, ci])
                       for ci, s in enumerate(req.candidate_list)}
                results[ri] = DecisionResult(
                    scaleout=req.candidate_list[sl],
                    predicted=float(totals_np[gi, sl]), totals=tot,
                    per_component_dev=per[gi],
                    n_candidates=len(req.candidate_list),
                    n_components=req.n_components)
        if groups:
            self.breaker.record(dispatch_ok)
        self.decisions += len(requests)
        if requests:
            share = (time.time() - t_start) / len(requests)
            for r in results:
                r.service_seconds = share
            if obs.enabled():
                hist = obs.registry().histogram(
                    "enel_decision_latency_seconds",
                    "per-request share of decide() wall time"
                ).labels(service=self.obs_name)
                for _ in requests:
                    hist.observe(share)
        return results

    # ----------------------------------------------------------- telemetry
    def stats(self) -> Dict:
        """All robustness counters + breaker state as one plain dict (the
        registry-backed successor of reading the attributes one by one)."""
        out = {attr: getattr(self, attr) for attr in _SERVICE_COUNTERS}
        out["breaker_trips"] = self.breaker_trips
        out["breaker_state"] = self.breaker.state
        return out

    # --------------------------------------------------- checkpoint support
    def snapshot_state(self) -> Dict:
        """Counters + breaker + jitter-RNG state for campaign checkpoints
        (the stack memo is a pure performance cache and is rebuilt)."""
        st = {"decisions": self.decisions, "dispatches": self.dispatches,
              "batched_away": self.batched_away,
              "fallback_decisions": self.fallback_decisions,
              "guardrail_trips": self.guardrail_trips,
              "retries": self.retries,
              "dispatch_failures": self.dispatch_failures,
              "shed_requests": self.shed_requests,
              "breaker": self.breaker.snapshot(),
              "rng": self._rng.get_state()}
        if self.fault_injector is not None and \
                hasattr(self.fault_injector, "snapshot"):
            st["fault_injector"] = self.fault_injector.snapshot()
        return st

    def restore_state(self, st: Dict) -> None:
        self.decisions = st["decisions"]
        self.dispatches = st["dispatches"]
        self.batched_away = st["batched_away"]
        self.fallback_decisions = st["fallback_decisions"]
        self.guardrail_trips = st["guardrail_trips"]
        self.retries = st["retries"]
        self.dispatch_failures = st["dispatch_failures"]
        self.shed_requests = st["shed_requests"]
        self.breaker.restore(st["breaker"])
        self._rng.set_state(st["rng"])
        if "fault_injector" in st and self.fault_injector is not None and \
                hasattr(self.fault_injector, "restore"):
            self.fault_injector.restore(st["fault_injector"])


def _install_counter_properties():
    """Expose the registry-backed service counters behind the original
    attribute API (``svc.retries``, ``svc.decisions += 1`` ...): reads and
    read-modify-writes hit the labeled CounterSeries in the obs registry."""
    def make(attr):
        def fget(self):
            return int(self._obs_counters[attr].value)

        def fset(self, value):
            self._obs_counters[attr].set(value)
        return property(fget, fset)

    for attr in _SERVICE_COUNTERS:
        setattr(DecisionService, attr, make(attr))


_install_counter_properties()
