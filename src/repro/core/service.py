"""Fleet-scale decision service: shape-bucketed, cross-job batched sweeps.

One rescaling decision is a (template, deltas) candidate sweep (see
``core/scaling.py``).  This module turns decisions into a batched,
recompilation-free service:

* every request arrives padded to the fixed shape ladders of
  :func:`repro.core.graph.bucket_sweep`, so the whole fleet shares a handful
  of jit shapes instead of one per exact sweep;
* requests with the same bucket key are stacked along a new job axis J
  (per-request model parameters included — each tenant keeps its own model)
  and evaluated in ONE jit dispatch, vmapped over the existing sweep
  assembly + the sparse-edge engine (:func:`~repro.core.model.sweep_sparse_totals`);
* the compliant-scale-out pick runs on device
  (:func:`~repro.core.model.pick_candidate`); the host fetches the picked
  indices and per-candidate totals in a single transfer, and the (J, C, K)
  per-component diagnostics stay on device until someone asks.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, defaultdict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ladder_bucket
from repro.core.model import (assemble_sweep_batch, pick_candidate,
                              record_trace, sweep_sparse_totals)

JOB_LADDER = (1, 2, 4, 8, 16, 32)       # job axis J (pad by repeating a row)


def _job_bucket(j: int) -> int:
    return ladder_bucket(j, JOB_LADDER)


def _stack_leaves(*xs):
    """Host leaves: one np.stack + one upload; device leaves: jnp.stack."""
    if isinstance(xs[0], np.ndarray):
        return jnp.asarray(np.stack(xs))
    return jnp.stack(xs)


@dataclasses.dataclass
class DecisionRequest:
    """One job's pending rescaling decision, already shape-bucketed.

    ``base``/``h_onehot`` may be device arrays (the scaler's template cache
    keeps them resident across decision points); ``deltas`` and the edge
    lists are fresh host arrays every decision.
    """
    params: Dict                      # this tenant's model parameters
    base: Dict                        # (K, N, ...) template arrays
    h_onehot: np.ndarray              # (K, N)
    deltas: Dict[str, np.ndarray]     # (C, K, ...)
    edge_dst: np.ndarray              # (K, E) int32
    edge_src: np.ndarray              # (K, E) int32
    edge_valid: np.ndarray            # (K, E) bool
    candidates: np.ndarray            # (C,) float32, padded ascending
    cand_valid: np.ndarray            # (C,) bool
    elapsed: float
    target: float
    levels: int
    candidate_list: List[int]         # the real candidate scale-outs
    n_components: int                 # real K (pre-padding)

    @property
    def bucket_key(self):
        k, n = self.h_onehot.shape
        return (len(self.candidates), k, n, self.edge_dst.shape[1],
                self.levels)


class DecisionResult:
    """Pick + totals (fetched in one transfer); per-component preds lazy.

    ``service_seconds`` is this request's amortized share of the service
    call that produced it — the runner bills it to the run's decision
    latency instead of timing across its generator suspension (which,
    under fleet interleaving, would charge one job for the whole round).
    """

    def __init__(self, scaleout: int, predicted: float,
                 totals: Dict[int, float], per_component_dev,
                 n_candidates: int, n_components: int):
        self.scaleout = scaleout
        self.predicted = predicted
        self.totals = totals
        self.service_seconds = 0.0
        self._per_dev = per_component_dev       # (C_bucket, K_bucket) device
        self._shape = (n_candidates, n_components)
        self._per_np: Optional[np.ndarray] = None

    @property
    def per_component(self) -> np.ndarray:
        """(C, K) per-component predictions; device->host on first access."""
        if self._per_np is None:
            c, k = self._shape
            self._per_np = np.asarray(self._per_dev)[:c, :k]
        return self._per_np


def _fleet_impl(params, base, h_onehot, deltas, edge_dst, edge_src,
                edge_valid, cand, cand_valid, elapsed, target, levels):
    """vmap over the job axis: assemble + sparse sweep + on-device pick."""
    record_trace("fleet_sweep")

    def one(p, b, oh, d, ed, es, ev, cd, cv, el, tg):
        c, k = d["a_raw"].shape[:2]
        flat = assemble_sweep_batch(b, oh, d)
        tile = lambda a: jnp.broadcast_to(
            a[None], (c,) + a.shape).reshape((c * k,) + a.shape[1:])
        per = sweep_sparse_totals(p, flat, tile(ed), tile(es), tile(ev),
                                  levels).reshape(c, k)
        totals = per.sum(axis=1) + el
        idx = pick_candidate(cd, cv, totals, tg)
        return idx, totals, per

    return jax.vmap(one)(params, base, h_onehot, deltas, edge_dst, edge_src,
                         edge_valid, cand, cand_valid, elapsed, target)


_fleet_jit = jax.jit(_fleet_impl, static_argnums=(11,))


def apply_capacity(request: DecisionRequest, max_scaleout: int
                   ) -> DecisionRequest:
    """Capacity-capped pick: mask candidates above ``max_scaleout`` (a
    multi-tenant executor-pool constraint) so the on-device compliant pick
    can only choose a scale-out the shrunken pool can actually grant.

    Returns ``request`` unchanged when the cap does not bind.  If the cap
    excludes every candidate, the smallest valid candidate stays eligible
    (a job never picks below the range floor; the pool accounting admits
    jobs only with at least that much headroom).
    """
    over = request.cand_valid & (request.candidates > max_scaleout)
    if not over.any():
        return request
    cv = request.cand_valid & ~over
    if not cv.any():
        lo = request.candidates[request.cand_valid].min()
        cv = request.cand_valid & (request.candidates <= lo)
    return dataclasses.replace(request, cand_valid=cv)


class DecisionService:
    """Collects concurrent decision requests and dispatches them batched.

    ``decide`` groups requests by bucket key, pads each group to a JOB_LADDER
    rung along the job axis, evaluates every group in one jit dispatch and
    fetches each group's picks + totals in a single host transfer.

    Dispatch is double-buffered by default: every group is stacked and
    dispatched first (jax dispatch is async), and the host transfers are
    fetched in a second pass — so host request-stacking of the next bucket
    overlaps device compute of the current one.  ``double_buffer=False``
    restores the synchronous stack->dispatch->fetch loop (decision parity
    between the two modes is asserted in tests).
    """

    def __init__(self, double_buffer: bool = True):
        self.double_buffer = double_buffer
        self.decisions = 0          # requests served
        self.dispatches = 0         # jit dispatches issued
        self.batched_away = 0       # dispatches saved vs one-per-request
        # identity-memoized stacks: params / template-base device arrays /
        # edge lists are object-stable across decision rounds (the scalers'
        # caches re-serve the same ndarrays while values are unchanged), so
        # their (J, ...) stacks are reused instead of re-stacked per round.
        # LRU-bounded so a long campaign over many bucket/fleet shapes
        # cannot pin stacked device arrays without limit.
        self._stack_memo: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._stack_memo_slots = 64

    def _stack_tree(self, cache_key: tuple, rows, get):
        trees = [get(r) for r in rows]
        all_leaves = [jax.tree_util.tree_leaves(t) for t in trees]
        ids = tuple(id(l) for row in all_leaves for l in row)
        hit = self._stack_memo.get(cache_key)
        if hit is not None and hit[0] == ids:
            self._stack_memo.move_to_end(cache_key)
            return hit[2]
        treedef = jax.tree_util.tree_structure(trees[0])
        stacked = jax.tree_util.tree_unflatten(
            treedef, [_stack_leaves(*col) for col in zip(*all_leaves)])
        # keep the leaf refs alive so the memo's ids cannot be recycled
        self._stack_memo[cache_key] = (ids, all_leaves, stacked)
        while len(self._stack_memo) > self._stack_memo_slots:
            self._stack_memo.popitem(last=False)
        return stacked

    def _dispatch_group(self, key: tuple, group: List[DecisionRequest]):
        """Stack one bucket group and issue its (async) jit dispatch."""
        j_b = _job_bucket(len(group))
        rows = group + [group[-1]] * (j_b - len(group))
        stack = lambda get: jax.tree_util.tree_map(
            _stack_leaves, *[get(r) for r in rows])
        out = _fleet_jit(
            self._stack_tree((key, j_b, "params"), rows,
                             lambda r: r.params),
            self._stack_tree((key, j_b, "base"), rows, lambda r: r.base),
            self._stack_tree((key, j_b, "h_onehot"), rows,
                             lambda r: r.h_onehot),
            stack(lambda r: r.deltas),
            self._stack_tree((key, j_b, "edge_dst"), rows,
                             lambda r: r.edge_dst),
            self._stack_tree((key, j_b, "edge_src"), rows,
                             lambda r: r.edge_src),
            self._stack_tree((key, j_b, "edge_valid"), rows,
                             lambda r: r.edge_valid),
            self._stack_tree((key, j_b, "candidates"), rows,
                             lambda r: r.candidates),
            self._stack_tree((key, j_b, "cand_valid"), rows,
                             lambda r: r.cand_valid),
            jnp.asarray([r.elapsed for r in rows], jnp.float32),
            jnp.asarray([r.target for r in rows], jnp.float32),
            group[0].levels)
        self.dispatches += 1
        self.batched_away += len(group) - 1
        return out

    def decide(self, requests: Sequence[DecisionRequest]
               ) -> List[DecisionResult]:
        t_start = time.time()
        groups: Dict[tuple, List[int]] = defaultdict(list)
        for i, r in enumerate(requests):
            groups[r.bucket_key].append(i)
        results: List[Optional[DecisionResult]] = [None] * len(requests)
        staged = []
        for key, idxs in groups.items():
            out = self._dispatch_group(key, [requests[i] for i in idxs])
            if not self.double_buffer:
                # synchronous mode: fetch before stacking the next bucket
                out = (jax.device_get(out[:2]), out[2])
            staged.append((idxs, out))
        for idxs, out in staged:
            if self.double_buffer:
                picked, totals, per = out
                # ONE host transfer per group: picks + per-candidate totals
                picked_np, totals_np = jax.device_get((picked, totals))
            else:
                (picked_np, totals_np), per = out
            for gi, ri in enumerate(idxs):
                req = requests[ri]
                sl = int(picked_np[gi])
                tot = {s: float(totals_np[gi, ci])
                       for ci, s in enumerate(req.candidate_list)}
                results[ri] = DecisionResult(
                    scaleout=req.candidate_list[sl],
                    predicted=float(totals_np[gi, sl]), totals=tot,
                    per_component_dev=per[gi],
                    n_candidates=len(req.candidate_list),
                    n_components=req.n_components)
        self.decisions += len(requests)
        if requests:
            share = (time.time() - t_start) / len(requests)
            for r in results:
                r.service_seconds = share
        return results
