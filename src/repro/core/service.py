"""Fleet-scale decision service: shape-bucketed, cross-job batched sweeps.

One rescaling decision is a (template, deltas) candidate sweep (see
``core/scaling.py``).  This module turns decisions into a batched,
recompilation-free service:

* every request arrives padded to the fixed shape ladders of
  :func:`repro.core.graph.bucket_sweep`, so the whole fleet shares a handful
  of jit shapes instead of one per exact sweep;
* requests with the same bucket key are stacked along a new job axis J
  (per-request model parameters included — each tenant keeps its own model)
  and evaluated in ONE jit dispatch, vmapped over the existing sweep
  assembly + the sparse-edge engine (:func:`~repro.core.model.sweep_sparse_totals`);
* the compliant-scale-out pick runs on device
  (:func:`~repro.core.model.pick_candidate`); the host fetches the picked
  indices and per-candidate totals in a single transfer, and the (J, C, K)
  per-component diagnostics stay on device until someone asks.

Fault tolerance (the control plane assumes the model CAN fail):

* a per-row on-device ``isfinite`` reduce
  (:func:`~repro.core.model.sweep_totals_ok`) rides the existing pick
  transfer; rows whose valid totals are non-finite are answered by the
  bounded model-free :class:`~repro.core.fallback.FallbackPolicy` instead
  of a poisoned pick;
* dispatch is wrapped in a retry envelope — capped exponential backoff with
  seeded jitter under a per-call deadline — and a :class:`CircuitBreaker`
  that trips the whole service into fallback mode after K consecutive
  failed dispatches, then half-opens on a probe cadence;
* overload shedding (the first piece of ROADMAP item 2's admission
  control): above ``shed_capacity`` pending requests per call, excess
  requests — best-effort ones first — are rejected to the fallback policy
  without touching the dispatch path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, defaultdict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fallback import FallbackPolicy
from repro.core.graph import ladder_bucket
from repro.core.model import (assemble_sweep_batch, pick_candidate,
                              record_trace, sweep_sparse_totals,
                              sweep_totals_ok)

JOB_LADDER = (1, 2, 4, 8, 16, 32)       # job axis J (pad by repeating a row)


class DispatchFault(RuntimeError):
    """A decision dispatch failed (retryable)."""


class DispatchTimeout(DispatchFault):
    """A decision dispatch exceeded its deadline (chaos injection raises
    this; a real deployment would raise it from an RPC timer)."""


def _job_bucket(j: int) -> int:
    return ladder_bucket(j, JOB_LADDER)


def _stack_leaves(*xs):
    """Host leaves: one np.stack + one upload; device leaves: jnp.stack."""
    if isinstance(xs[0], np.ndarray):
        return jnp.asarray(np.stack(xs))
    return jnp.stack(xs)


@dataclasses.dataclass
class DecisionRequest:
    """One job's pending rescaling decision, already shape-bucketed.

    ``base``/``h_onehot`` may be device arrays (the scaler's template cache
    keeps them resident across decision points); ``deltas`` and the edge
    lists are fresh host arrays every decision.

    ``current_scaleout`` carries the requester's live allocation so a
    fallback answer can step FROM somewhere; ``best_effort`` marks requests
    the service may shed first under overload.
    """
    params: Dict                      # this tenant's model parameters
    base: Dict                        # (K, N, ...) template arrays
    h_onehot: np.ndarray              # (K, N)
    deltas: Dict[str, np.ndarray]     # (C, K, ...)
    edge_dst: np.ndarray              # (K, E) int32
    edge_src: np.ndarray              # (K, E) int32
    edge_valid: np.ndarray            # (K, E) bool
    candidates: np.ndarray            # (C,) float32, padded ascending
    cand_valid: np.ndarray            # (C,) bool
    elapsed: float
    target: float
    levels: int
    candidate_list: List[int]         # the real candidate scale-outs
    n_components: int                 # real K (pre-padding)
    current_scaleout: int = 0         # requester's live allocation
    best_effort: bool = False         # sheddable under overload

    @property
    def bucket_key(self):
        k, n = self.h_onehot.shape
        return (len(self.candidates), k, n, self.edge_dst.shape[1],
                self.levels)


class DecisionResult:
    """Pick + totals (fetched in one transfer); per-component preds lazy.

    ``service_seconds`` is this request's amortized share of the service
    call that produced it — the runner bills it to the run's decision
    latency instead of timing across its generator suspension (which,
    under fleet interleaving, would charge one job for the whole round).

    ``fallback``/``shed`` flag decisions the model did not make: answered
    by the heuristic policy (guardrail trip, breaker open, retries
    exhausted) or rejected under overload, respectively.
    """

    def __init__(self, scaleout: int, predicted: float,
                 totals: Dict[int, float], per_component_dev,
                 n_candidates: int, n_components: int):
        self.scaleout = scaleout
        self.predicted = predicted
        self.totals = totals
        self.service_seconds = 0.0
        self.fallback = False
        self.shed = False
        self._per_dev = per_component_dev       # (C_bucket, K_bucket) device
        self._shape = (n_candidates, n_components)
        self._per_np: Optional[np.ndarray] = None

    @property
    def per_component(self) -> np.ndarray:
        """(C, K) per-component predictions; device->host on first access.
        Fallback decisions carry no sweep: their diagnostics read as 0."""
        if self._per_np is None:
            if self._per_dev is None:
                self._per_np = np.zeros(self._shape, np.float32)
            else:
                c, k = self._shape
                self._per_np = np.asarray(self._per_dev)[:c, :k]
        return self._per_np


def sweep_eval_one(p, b, oh, d, ed, es, ev, cd, cv, el, tg, levels):
    """One job's sweep: assemble + sparse totals + on-device compliant pick.

    Returns (pick index, per-candidate totals, (C, K) per-component
    predictions, finite-totals ok flag).  Module-level so the fused campaign
    kernel (``core/campaign_kernel.py``) evaluates decisions with EXACTLY the
    ops the fleet service dispatches — one numerics contract, two drivers.
    """
    c, k = d["a_raw"].shape[:2]
    flat = assemble_sweep_batch(b, oh, d)
    tile = lambda a: jnp.broadcast_to(
        a[None], (c,) + a.shape).reshape((c * k,) + a.shape[1:])
    per = sweep_sparse_totals(p, flat, tile(ed), tile(es), tile(ev),
                              levels).reshape(c, k)
    totals = per.sum(axis=1) + el
    idx = pick_candidate(cd, cv, totals, tg)
    ok = sweep_totals_ok(totals, cv)
    return idx, totals, per, ok


def _fleet_impl(params, base, h_onehot, deltas, edge_dst, edge_src,
                edge_valid, cand, cand_valid, elapsed, target, levels):
    """vmap over the job axis: assemble + sparse sweep + on-device pick.

    Returns per job row (pick index, per-candidate totals, (C, K)
    per-component predictions, finite-totals ok flag).  The ok reduce is
    folded into this dispatch so the guardrail costs no extra dispatch and
    rides the existing pick+totals transfer.
    """
    record_trace("fleet_sweep")

    def one(p, b, oh, d, ed, es, ev, cd, cv, el, tg):
        return sweep_eval_one(p, b, oh, d, ed, es, ev, cd, cv, el, tg,
                              levels)

    return jax.vmap(one)(params, base, h_onehot, deltas, edge_dst, edge_src,
                         edge_valid, cand, cand_valid, elapsed, target)


_fleet_jit = jax.jit(_fleet_impl, static_argnums=(11,))


def apply_capacity(request: DecisionRequest, max_scaleout: int
                   ) -> DecisionRequest:
    """Capacity-capped pick: mask candidates above ``max_scaleout`` (a
    multi-tenant executor-pool constraint) so the on-device compliant pick
    can only choose a scale-out the shrunken pool can actually grant.

    Returns ``request`` unchanged when the cap does not bind.  If the cap
    excludes every candidate, the smallest valid candidate stays eligible
    (a job never picks below the range floor; the pool accounting admits
    jobs only with at least that much headroom).
    """
    over = request.cand_valid & (request.candidates > max_scaleout)
    if not over.any():
        return request
    cv = request.cand_valid & ~over
    if not cv.any():
        lo = request.candidates[request.cand_valid].min()
        cv = request.cand_valid & (request.candidates <= lo)
    return dataclasses.replace(request, cand_valid=cv)


class CircuitBreaker:
    """Dispatch-path circuit breaker: CLOSED -> OPEN after ``threshold``
    consecutive failed dispatch calls; OPEN serves every request from the
    fallback policy; after ``probe_after`` blocked calls the breaker
    HALF-OPENs and lets one probe call through — success closes it,
    failure re-opens (counting another trip)."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 3, probe_after: int = 4):
        self.threshold = int(threshold)
        self.probe_after = int(probe_after)
        self.state = self.CLOSED
        self.consecutive_failures = 0
        self.trips = 0
        self._blocked_calls = 0

    def allow(self) -> bool:
        """One call per service decide(): may this call dispatch?"""
        if self.state == self.OPEN:
            self._blocked_calls += 1
            if self._blocked_calls >= self.probe_after:
                self.state = self.HALF_OPEN
            return False
        return True                     # closed, or half-open (the probe)

    def record(self, success: bool) -> None:
        if success:
            self.consecutive_failures = 0
            self.state = self.CLOSED
            return
        self.consecutive_failures += 1
        if self.state == self.HALF_OPEN or \
                self.consecutive_failures >= self.threshold:
            self.state = self.OPEN
            self._blocked_calls = 0
            self.trips += 1

    def snapshot(self) -> Dict:
        return {"state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "trips": self.trips,
                "blocked_calls": self._blocked_calls}

    def restore(self, st: Dict) -> None:
        self.state = st["state"]
        self.consecutive_failures = st["consecutive_failures"]
        self.trips = st["trips"]
        self._blocked_calls = st["blocked_calls"]


class DecisionService:
    """Collects concurrent decision requests and dispatches them batched.

    ``decide`` groups requests by bucket key, pads each group to a JOB_LADDER
    rung along the job axis, evaluates every group in one jit dispatch and
    fetches each group's picks + totals in a single host transfer.

    Dispatch is double-buffered by default: every group is stacked and
    dispatched first (jax dispatch is async), and the host transfers are
    fetched in a second pass — so host request-stacking of the next bucket
    overlaps device compute of the current one.  ``double_buffer=False``
    restores the synchronous stack->dispatch->fetch loop (decision parity
    between the two modes is asserted in tests).

    Failure envelope: each group dispatch retries up to ``max_retries``
    times under capped exponential backoff with seeded jitter, bounded by
    ``deadline_s`` per decide() call; consecutive decide() calls whose
    dispatches fail trip the :class:`CircuitBreaker` into fallback-for-all
    mode.  Rows whose predictions come back non-finite are answered by the
    :class:`~repro.core.fallback.FallbackPolicy` WITHOUT tripping the
    breaker (a poisoned tenant model is a per-row condition, not a service
    outage; its fallback rate is visible in the counters).  ``fault_injector``
    is the chaos hook: a callable invoked once per dispatch attempt that
    may raise :class:`DispatchFault`.
    """

    def __init__(self, double_buffer: bool = True, *,
                 fallback: Optional[FallbackPolicy] = None,
                 max_retries: int = 2, backoff_base_s: float = 0.02,
                 backoff_cap_s: float = 0.25,
                 deadline_s: Optional[float] = None,
                 breaker_threshold: int = 3, breaker_probe_after: int = 4,
                 shed_capacity: Optional[int] = None, seed: int = 0):
        self.double_buffer = double_buffer
        self.fallback = fallback or FallbackPolicy()
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.deadline_s = deadline_s
        self.breaker = CircuitBreaker(breaker_threshold, breaker_probe_after)
        self.shed_capacity = shed_capacity
        self.fault_injector = None      # chaos hook (see repro.sim.chaos)
        self._rng = np.random.RandomState(seed ^ 0xbac0ff)  # backoff jitter
        self.decisions = 0          # requests served
        self.dispatches = 0         # jit dispatches issued
        self.batched_away = 0       # dispatches saved vs one-per-request
        self.fallback_decisions = 0  # requests answered by the policy
        self.guardrail_trips = 0    # ... of which: non-finite sweep rows
        self.retries = 0            # dispatch attempts beyond the first
        self.dispatch_failures = 0  # failed dispatch attempts (incl. retried)
        self.shed_requests = 0      # requests rejected under overload
        # identity-memoized stacks: params / template-base device arrays /
        # edge lists are object-stable across decision rounds (the scalers'
        # caches re-serve the same ndarrays while values are unchanged), so
        # their (J, ...) stacks are reused instead of re-stacked per round.
        # LRU-bounded so a long campaign over many bucket/fleet shapes
        # cannot pin stacked device arrays without limit.
        self._stack_memo: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._stack_memo_slots = 64

    @property
    def breaker_trips(self) -> int:
        return self.breaker.trips

    def _stack_tree(self, cache_key: tuple, rows, get):
        trees = [get(r) for r in rows]
        all_leaves = [jax.tree_util.tree_leaves(t) for t in trees]
        ids = tuple(id(l) for row in all_leaves for l in row)
        hit = self._stack_memo.get(cache_key)
        if hit is not None and hit[0] == ids:
            self._stack_memo.move_to_end(cache_key)
            return hit[2]
        treedef = jax.tree_util.tree_structure(trees[0])
        stacked = jax.tree_util.tree_unflatten(
            treedef, [_stack_leaves(*col) for col in zip(*all_leaves)])
        # keep the leaf refs alive so the memo's ids cannot be recycled
        self._stack_memo[cache_key] = (ids, all_leaves, stacked)
        while len(self._stack_memo) > self._stack_memo_slots:
            self._stack_memo.popitem(last=False)
        return stacked

    def _dispatch_group(self, key: tuple, group: List[DecisionRequest]):
        """Stack one bucket group and issue its (async) jit dispatch."""
        if self.fault_injector is not None:
            self.fault_injector()       # chaos: may raise DispatchFault
        j_b = _job_bucket(len(group))
        rows = group + [group[-1]] * (j_b - len(group))
        stack = lambda get: jax.tree_util.tree_map(
            _stack_leaves, *[get(r) for r in rows])
        out = _fleet_jit(
            self._stack_tree((key, j_b, "params"), rows,
                             lambda r: r.params),
            self._stack_tree((key, j_b, "base"), rows, lambda r: r.base),
            self._stack_tree((key, j_b, "h_onehot"), rows,
                             lambda r: r.h_onehot),
            stack(lambda r: r.deltas),
            self._stack_tree((key, j_b, "edge_dst"), rows,
                             lambda r: r.edge_dst),
            self._stack_tree((key, j_b, "edge_src"), rows,
                             lambda r: r.edge_src),
            self._stack_tree((key, j_b, "edge_valid"), rows,
                             lambda r: r.edge_valid),
            self._stack_tree((key, j_b, "candidates"), rows,
                             lambda r: r.candidates),
            self._stack_tree((key, j_b, "cand_valid"), rows,
                             lambda r: r.cand_valid),
            jnp.asarray([r.elapsed for r in rows], jnp.float32),
            jnp.asarray([r.target for r in rows], jnp.float32),
            group[0].levels)
        self.dispatches += 1
        self.batched_away += len(group) - 1
        return out

    # ------------------------------------------------------ failure envelope
    def _fallback_result(self, req: DecisionRequest,
                         totals_row: Optional[np.ndarray] = None,
                         shed: bool = False) -> DecisionResult:
        """Answer one request from the bounded heuristic policy."""
        totals = None
        if totals_row is not None:
            totals = {s: float(totals_row[ci])
                      for ci, s in enumerate(req.candidate_list)}
        s, pred = self.fallback.decide(
            req.candidate_list, totals, req.current_scaleout,
            req.elapsed, req.target)
        res = DecisionResult(
            scaleout=int(s), predicted=pred,
            totals=self.fallback._finite_totals(req.candidate_list, totals),
            per_component_dev=None,
            n_candidates=len(req.candidate_list),
            n_components=req.n_components)
        res.fallback = True
        res.shed = shed
        self.fallback_decisions += 1
        if shed:
            self.shed_requests += 1
        return res

    def _dispatch_with_retry(self, key: tuple,
                             group: List[DecisionRequest],
                             t_start: float, deadline: Optional[float]):
        """Dispatch one group under the retry/backoff/deadline envelope;
        returns the jit output or None when the envelope is exhausted."""
        attempt = 0
        while True:
            try:
                return self._dispatch_group(key, group)
            except DispatchFault:
                self.dispatch_failures += 1
                sleep = min(self.backoff_cap_s,
                            self.backoff_base_s * (2 ** attempt))
                sleep *= 0.5 + self._rng.rand()     # seeded jitter
                if attempt >= self.max_retries or (
                        deadline is not None and
                        time.time() - t_start + sleep > deadline):
                    return None
                time.sleep(sleep)
                self.retries += 1
                attempt += 1

    def _shed(self, requests: Sequence[DecisionRequest],
              results: List[Optional[DecisionResult]]) -> List[int]:
        """Admission control: above ``shed_capacity`` pending requests,
        reject the excess — best-effort requests first, newest first —
        straight to the fallback policy.  Returns the surviving indices."""
        live = list(range(len(requests)))
        if self.shed_capacity is None or len(live) <= self.shed_capacity:
            return live
        excess = len(live) - int(self.shed_capacity)
        order = [i for i in reversed(live) if requests[i].best_effort] + \
                [i for i in reversed(live) if not requests[i].best_effort]
        for i in order[:excess]:
            results[i] = self._fallback_result(requests[i], shed=True)
        return [i for i in live if results[i] is None]

    def decide(self, requests: Sequence[DecisionRequest]
               ) -> List[DecisionResult]:
        t_start = time.time()
        results: List[Optional[DecisionResult]] = [None] * len(requests)
        live = self._shed(requests, results)
        if live and not self.breaker.allow():       # open: fallback for all
            for i in live:
                results[i] = self._fallback_result(requests[i])
            live = []
        groups: Dict[tuple, List[int]] = defaultdict(list)
        for i in live:
            groups[requests[i].bucket_key].append(i)
        deadline = self.deadline_s
        staged = []
        dispatch_ok = True
        for key, idxs in groups.items():
            out = self._dispatch_with_retry(
                key, [requests[i] for i in idxs], t_start, deadline)
            if out is None:                         # envelope exhausted
                dispatch_ok = False
                for i in idxs:
                    results[i] = self._fallback_result(requests[i])
                continue
            if not self.double_buffer:
                # synchronous mode: fetch before stacking the next bucket
                out = (jax.device_get((out[0], out[1], out[3])), out[2])
            staged.append((idxs, out))
        for idxs, out in staged:
            if self.double_buffer:
                picked, totals, per, ok = out
                # ONE host transfer per group: picks + totals + ok flags
                picked_np, totals_np, ok_np = jax.device_get(
                    (picked, totals, ok))
            else:
                (picked_np, totals_np, ok_np), per = out
            for gi, ri in enumerate(idxs):
                req = requests[ri]
                if not bool(ok_np[gi]):     # guardrail: poisoned sweep row
                    self.guardrail_trips += 1
                    results[ri] = self._fallback_result(
                        req, totals_row=totals_np[gi])
                    continue
                sl = int(picked_np[gi])
                tot = {s: float(totals_np[gi, ci])
                       for ci, s in enumerate(req.candidate_list)}
                results[ri] = DecisionResult(
                    scaleout=req.candidate_list[sl],
                    predicted=float(totals_np[gi, sl]), totals=tot,
                    per_component_dev=per[gi],
                    n_candidates=len(req.candidate_list),
                    n_components=req.n_components)
        if groups:
            self.breaker.record(dispatch_ok)
        self.decisions += len(requests)
        if requests:
            share = (time.time() - t_start) / len(requests)
            for r in results:
                r.service_seconds = share
        return results

    # --------------------------------------------------- checkpoint support
    def snapshot_state(self) -> Dict:
        """Counters + breaker + jitter-RNG state for campaign checkpoints
        (the stack memo is a pure performance cache and is rebuilt)."""
        st = {"decisions": self.decisions, "dispatches": self.dispatches,
              "batched_away": self.batched_away,
              "fallback_decisions": self.fallback_decisions,
              "guardrail_trips": self.guardrail_trips,
              "retries": self.retries,
              "dispatch_failures": self.dispatch_failures,
              "shed_requests": self.shed_requests,
              "breaker": self.breaker.snapshot(),
              "rng": self._rng.get_state()}
        if self.fault_injector is not None and \
                hasattr(self.fault_injector, "snapshot"):
            st["fault_injector"] = self.fault_injector.snapshot()
        return st

    def restore_state(self, st: Dict) -> None:
        self.decisions = st["decisions"]
        self.dispatches = st["dispatches"]
        self.batched_away = st["batched_away"]
        self.fallback_decisions = st["fallback_decisions"]
        self.guardrail_trips = st["guardrail_trips"]
        self.retries = st["retries"]
        self.dispatch_failures = st["dispatch_failures"]
        self.shed_requests = st["shed_requests"]
        self.breaker.restore(st["breaker"])
        self._rng.set_state(st["rng"])
        if "fault_injector" in st and self.fault_injector is not None and \
                hasattr(self.fault_injector, "restore"):
            self.fault_injector.restore(st["fault_injector"])
