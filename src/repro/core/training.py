"""Enel model training / fine-tuning (paper §IV-A, §V-B.3).

Targets: observed node runtimes, observed rescale overheads and observed
metric vectors (propagation loss).  Adam over the ~5k-parameter model; a
"retrain from scratch every 5th run, fine-tune in between" policy mirroring
the paper's protocol lives in :class:`EnelTrainer`.

Two fit routes share the loss/optimizer math:

* ``EnelTrainer.fit`` — legacy list-of-graphs API: host restack + power-of-2
  bucketing + a frozen metric-dropout copy appended to the batch.
* ``EnelTrainer.fit_resident`` — the online fast path: trains directly on the
  device-resident :class:`~repro.core.graph.TrainingCache` ring buffer (fed
  incrementally by the runner), with metric dropout sampled on-device PER
  STEP inside the scanned Adam loop (fresh mask each step, no 2x batch) and
  per-slot weights selecting the scratch window vs. the newest run.  Both
  differentiate through ``forward_stacked`` and so honour the fused
  graph-prop kernel flag (custom VJP).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import model as enel_model
from repro.core.graph import (ComponentGraph, TrainingCache, pow2_bucket,
                              stack_graphs)

HUBER_DELTA = 10.0

# trainer non-finite-guard telemetry: attribute -> (family, kind, help).
# Registered in the unified obs registry behind the original attribute API.
_TRAINER_COUNTERS = {
    "nonfinite_steps": ("enel_trainer_nonfinite_steps_total", "counter",
                        "Adam steps skipped by the non-finite guard"),
    "last_skipped_steps": ("enel_trainer_last_skipped_steps", "gauge",
                           "guard-skipped steps in the most recent fit"),
    "poisoned_fits": ("enel_trainer_poisoned_fits_total", "counter",
                      "fits where every step was guard-skipped"),
}


def _huber(err: jax.Array, delta: float = HUBER_DELTA) -> jax.Array:
    a = jnp.abs(err)
    return jnp.where(a <= delta, 0.5 * err * err, delta * (a - 0.5 * delta))


def enel_loss(params: Dict, batch: Dict, weights: Optional[jax.Array] = None,
              use_kernel: bool = False) -> Tuple[jax.Array, Dict]:
    """Training loss over a stacked graph batch.

    ``weights`` (B,) 0/1 scales each graph's contribution (ring-buffer slots
    outside the training window); ``use_kernel`` routes eqs. 6-7 through the
    fused Pallas kernel + its custom VJP (resolve the flag before jitting).
    """
    out = enel_model.forward_stacked(params, batch, use_kernel=use_kernel)
    rt_mask = batch["runtime_valid"] & batch["mask"] & ~batch["is_summary"]
    rt_err = jnp.where(rt_mask, out["runtime"] - batch["runtime"], 0.0)

    ov_mask = batch["overhead_valid"] & batch["mask"]
    ov_err = jnp.where(ov_mask, out["overhead"] - batch["overhead"], 0.0)

    # metric propagation loss: predict observed metrics from predecessors
    m_mask = (batch["metrics_valid"] & batch["mask"])[..., None]
    m_err = jnp.where(m_mask, out["metrics"] - batch["metrics"], 0.0)

    if weights is None:
        l_rt = jnp.sum(_huber(rt_err)) / jnp.maximum(rt_mask.sum(), 1)
        l_ov = jnp.sum(_huber(ov_err)) / jnp.maximum(ov_mask.sum(), 1)
        l_m = jnp.sum(jnp.square(m_err)) / jnp.maximum(m_mask.sum(), 1)
    else:
        w1 = weights[:, None]
        l_rt = jnp.sum(_huber(rt_err) * w1) / \
            jnp.maximum(jnp.sum(rt_mask * w1), 1.0)
        l_ov = jnp.sum(_huber(ov_err) * w1) / \
            jnp.maximum(jnp.sum(ov_mask * w1), 1.0)
        w2 = weights[:, None, None]
        l_m = jnp.sum(jnp.square(m_err) * w2) / \
            jnp.maximum(jnp.sum(m_mask * w2), 1.0)

    loss = l_rt + l_ov + 0.5 * l_m
    return loss, {"runtime": l_rt, "overhead": l_ov, "metrics": l_m}


def _adam_update(params, opt, batch, lr, weights=None, use_kernel=False):
    """One guarded Adam step: a step whose loss or gradients come back
    non-finite is SKIPPED (params/opt unchanged, ``ok=False``) instead of
    writing NaN into the parameters — one poisoned batch row or a
    divergent step can no longer destroy the model."""
    (loss, parts), g = jax.value_and_grad(enel_loss, has_aux=True)(
        params, batch, weights, use_kernel)
    ok = jnp.isfinite(loss)
    for leaf in jax.tree_util.tree_leaves(g):
        ok = ok & jnp.all(jnp.isfinite(leaf))
    mu0, nu0, t0 = opt
    t = t0 + 1
    mu = jax.tree_util.tree_map(lambda m, gg: 0.9 * m + 0.1 * gg, mu0, g)
    nu = jax.tree_util.tree_map(lambda v, gg: 0.999 * v + 0.001 * gg * gg,
                                nu0, g)

    def upd(p, m, v):
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        return p - lr * mh / (jnp.sqrt(vh) + 1e-8)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    sel = lambda a, b: jax.tree_util.tree_map(
        lambda x, y: jnp.where(ok, x, y), a, b)
    return sel(new_params, params), \
        (sel(mu, mu0), sel(nu, nu0), jnp.where(ok, t, t0)), loss, ok


def _adam_run_impl(params, opt, batch, steps, lr, use_kernel=False):
    """`steps` Adam updates fused into one jit (dispatch-bound otherwise);
    also returns how many steps the non-finite guard skipped."""
    def body(carry, _):
        p, o = carry
        p, o, loss, ok = _adam_update(p, o, batch, lr, None, use_kernel)
        return (p, o), (loss, ok)

    (params, opt), (losses, oks) = jax.lax.scan(body, (params, opt), None,
                                                length=steps)
    return params, opt, losses[-1], steps - jnp.sum(oks)


_adam_run = jax.jit(_adam_run_impl, static_argnums=(3, 5))
# params/opt are replaced by the returned pytrees every call -> donating their
# buffers avoids a copy per fit; donation is a no-op (warning) on CPU, so the
# donated variant is only selected off-CPU.
_adam_run_donated = jax.jit(_adam_run_impl, static_argnums=(3, 5),
                            donate_argnums=(0, 1))


@functools.lru_cache(maxsize=1)
def _adam_run_fn():
    return _adam_run if jax.default_backend() == "cpu" else _adam_run_donated


def _adam_run_resident_impl(params, opt, batch, weights, key, lr, dropout_p,
                            steps, use_kernel):
    """Scanned Adam over a resident batch with PER-STEP metric dropout.

    Each step samples a fresh on-device mask hiding task-set metrics with
    probability ``dropout_p`` (summary nodes kept), so runtime prediction is
    trained through the metric-PROPAGATION path — the legacy route froze one
    host-sampled mask and doubled the batch instead.
    """
    def body(carry, _):
        p, o, k = carry
        k, sub = jax.random.split(k)
        drop = (jax.random.uniform(sub, batch["metrics_valid"].shape)
                < dropout_p) & ~batch["is_summary"]
        b = dict(batch, metrics_valid=batch["metrics_valid"] & ~drop)
        p, o, loss, ok = _adam_update(p, o, b, lr, weights, use_kernel)
        return (p, o, k), (loss, ok)

    (params, opt, _), (losses, oks) = jax.lax.scan(body, (params, opt, key),
                                                   None, length=steps)
    return params, opt, losses[-1], steps - jnp.sum(oks)


_adam_run_resident = jax.jit(_adam_run_resident_impl, static_argnums=(7, 8))
# batch/weights live in the TrainingCache and MUST NOT be donated; params/opt
# follow the same replace-every-call pattern as the legacy run.
_adam_run_resident_donated = jax.jit(_adam_run_resident_impl,
                                     static_argnums=(7, 8),
                                     donate_argnums=(0, 1))


@functools.lru_cache(maxsize=1)
def _adam_run_resident_fn():
    return _adam_run_resident if jax.default_backend() == "cpu" \
        else _adam_run_resident_donated


def _round_steps(steps: int) -> int:
    """Round DOWN to a power of two in [8, 512] (jit cache friendliness;
    the floor keeps step counts comparable with the historical fit rows)."""
    p2 = 1 << max(0, (max(steps, 1)).bit_length() - 1)
    return max(8, min(512, p2 if steps - p2 < p2 else p2 * 2))


class EnelTrainer:
    """One global reusable model + the paper's (re)training cadence."""

    _ids = itertools.count()        # default obs label allocator

    def __init__(self, seed: int = 0, lr: float = 5e-3,
                 cache_capacity: int = 96, obs_name: Optional[str] = None):
        self.seed = seed
        self.lr = lr
        self.params = enel_model.init_enel(jax.random.PRNGKey(seed))
        self._reset_opt()
        self.runs_seen = 0
        self.last_fit_seconds = 0.0
        # device-resident history ring for the online fast path (lazy: sized
        # to the first graphs seen); legacy fit() keeps working without it
        self.cache: Optional[TrainingCache] = None
        self.cache_capacity = cache_capacity
        self._fit_calls = 0
        # non-finite guard telemetry (see _adam_update): registry-backed
        # behind the original attribute API (nonfinite_steps /
        # last_skipped_steps / poisoned_fits properties below)
        self.obs_name = obs_name or f"tr{next(self._ids)}"
        reg = obs.registry()
        self._obs_counters = {
            attr: (reg.counter(fam, help) if kind == "counter"
                   else reg.gauge(fam, help)).labels(trainer=self.obs_name)
            for attr, (fam, kind, help) in _TRAINER_COUNTERS.items()}

    def _emit_fit(self, route: str, scratch: bool, steps: int, loss: float,
                  retried: bool = False) -> None:
        obs.emit("fit", trainer=self.obs_name, route=route,
                 mode="scratch" if scratch else "tune", steps=steps,
                 skipped=self.last_skipped_steps, retried=retried,
                 loss=round(float(loss), 6),
                 seconds=round(self.last_fit_seconds, 6))
        obs.observe("enel_fit_seconds", self.last_fit_seconds,
                    trainer=self.obs_name,
                    mode="scratch" if scratch else "tune")

    def _reset_opt(self):
        zeros = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        self.opt = (zeros, jax.tree_util.tree_map(jnp.zeros_like, self.params),
                    jnp.zeros((), jnp.int32))

    def n_params(self) -> int:
        return enel_model.n_params(self.params)

    def fit(self, graphs: Sequence[ComponentGraph], *, steps: int = 200,
            from_scratch: bool = False, metric_dropout: float = 0.5) -> float:
        """Train on a set of component graphs; returns final loss.

        ``metric_dropout`` appends a copy of the batch with task-set metrics
        masked out (summary nodes kept), so runtime prediction is also trained
        through the metric-PROPAGATION path — the exact configuration used
        during online inference on not-yet-executed iterations (§III-D).
        """
        if not graphs:
            return float("nan")
        t0 = time.time()
        if from_scratch:
            self.params = enel_model.init_enel(jax.random.PRNGKey(self.seed))
            self._reset_opt()
        graphs = list(graphs)
        # bucket the batch to a power of two with empty (all-masked) graphs so
        # jit caches a handful of shapes instead of one per history length
        from repro.core.graph import empty_graph
        n = len(graphs)
        graphs = graphs + [empty_graph()] * (pow2_bucket(n) - n)
        stacked = stack_graphs(graphs)
        if metric_dropout > 0:
            rng = np.random.RandomState(self.seed + self.runs_seen)
            aug = {k: v.copy() for k, v in stacked.items()}
            drop = (rng.rand(*aug["metrics_valid"].shape) < metric_dropout)
            drop &= ~aug["is_summary"]
            aug["metrics_valid"] = aug["metrics_valid"] & ~drop
            stacked = {k: np.concatenate([stacked[k], aug[k]])
                       for k in stacked}
        batch = {k: jnp.asarray(v) for k, v in stacked.items()}
        steps = _round_steps(steps)
        self.params, self.opt, loss, skipped = _adam_run_fn()(
            self.params, self.opt, batch, steps, self.lr,
            enel_model.graph_prop_kernel_enabled())
        self._note_skipped(skipped, steps)
        self.last_fit_seconds = time.time() - t0
        loss = float(loss)
        self._emit_fit("legacy", from_scratch, steps, loss)
        return loss

    def _note_skipped(self, skipped, steps: int) -> None:
        self.last_skipped_steps = int(skipped)
        self.nonfinite_steps += self.last_skipped_steps
        if self.last_skipped_steps >= steps:
            self.poisoned_fits += 1

    def params_finite(self) -> bool:
        """True iff every model parameter is finite (one host fetch)."""
        return all(bool(np.isfinite(np.asarray(l)).all())
                   for l in jax.tree_util.tree_leaves(self.params))

    # ------------------------------------------------- resident fast path
    def extend_history(self, graphs: Sequence[ComponentGraph]) -> None:
        """Append a run's graphs to the device-resident training ring (the
        runner calls this once per run; fits then reuse the buffers)."""
        graphs = list(graphs)
        if not graphs:
            return
        if self.cache is None:
            self.cache = TrainingCache(self.cache_capacity)
        self.cache.extend(graphs)

    def fit_resident(self, *, steps: int = 200, from_scratch: bool = False,
                     metric_dropout: float = 0.5,
                     latest_only: bool = False,
                     _retry: bool = True) -> float:
        """Train on the resident ring buffer; returns final loss.

        ``latest_only`` restricts the loss to the newest ``extend_history``
        batch (the paper's fine-tune step) via a gathered power-of-two slice;
        otherwise the whole ring (scratch-retrain window) trains with
        per-slot weights masking unfilled slots.  Metric dropout is sampled
        on-device per Adam step (see ``_adam_run_resident_impl``).

        The non-finite guard skips poisoned steps instead of writing NaN
        params (counted in ``nonfinite_steps``); a fit where EVERY step was
        skipped triggers one cache :meth:`~repro.core.graph.TrainingCache.
        quarantine_nonfinite` sweep and a single retry — self-healing after
        in-place cache corruption.
        """
        if self.cache is None or self.cache.count == 0:
            return float("nan")
        t0 = time.time()
        if from_scratch:
            self.params = enel_model.init_enel(jax.random.PRNGKey(self.seed))
            self._reset_opt()
        batch, weights = (self.cache.latest_batch() if latest_only
                          else self.cache.full_batch())
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed ^ 0x5eed),
                                 self._fit_calls)
        self._fit_calls += 1
        use_kernel = enel_model.graph_prop_kernel_enabled()
        n_steps = _round_steps(steps)
        self.params, self.opt, loss, skipped = _adam_run_resident_fn()(
            self.params, self.opt, batch, jnp.asarray(weights), key, self.lr,
            float(metric_dropout), n_steps, use_kernel)
        self._note_skipped(skipped, n_steps)
        self.last_fit_seconds = time.time() - t0
        if self.last_skipped_steps >= n_steps and _retry and \
                self.params_finite() and \
                self.cache.quarantine_nonfinite() > 0:
            # params were fine but the batch was poisoned: the corrupt rows
            # are quarantined now, so one retry trains on the healed ring
            self._emit_fit("resident", from_scratch, n_steps, float(loss),
                           retried=True)
            return self.fit_resident(steps=steps, from_scratch=from_scratch,
                                     metric_dropout=metric_dropout,
                                     latest_only=latest_only, _retry=False)
        loss = float(loss)
        self._emit_fit("resident", from_scratch, n_steps, loss)
        return loss

    def observe_run_resident(self, *, retrain_every: int = 5,
                             steps: int = 200,
                             fine_tune_steps: int = 60) -> float:
        """Paper cadence (§V-B.3) on the resident ring: scratch-retrain on
        the full history window every `retrain_every` runs, fine-tune on the
        newest run's graphs (the last ``extend_history``) in between."""
        self.runs_seen += 1
        if (self.runs_seen % retrain_every) == 0:
            return self.fit_resident(steps=steps, from_scratch=True)
        return self.fit_resident(steps=fine_tune_steps, latest_only=True)

    def observe_run(self, latest: Sequence[ComponentGraph],
                    history: Optional[Sequence[ComponentGraph]] = None,
                    retrain_every: int = 5, steps: int = 200,
                    fine_tune_steps: int = 60) -> float:
        """Paper cadence (§V-B.3): train a new model from scratch on the
        history window every `retrain_every` runs, fine-tune on the newest
        run's graphs in between."""
        self.runs_seen += 1
        scratch = (self.runs_seen % retrain_every) == 0 and history is not None
        if scratch:
            return self.fit(history, steps=steps, from_scratch=True)
        return self.fit(latest, steps=fine_tune_steps)

    # --------------------------------------------------- checkpoint support
    def snapshot_state(self) -> Dict:
        """Picklable host copy of params/opt/cadence/ring state (campaign
        checkpoints; see dataflow/fleet.py)."""
        host = lambda t: jax.tree_util.tree_map(np.asarray, t)
        return {"params": host(self.params), "opt": host(self.opt),
                "runs_seen": self.runs_seen, "fit_calls": self._fit_calls,
                "nonfinite_steps": self.nonfinite_steps,
                "last_skipped_steps": self.last_skipped_steps,
                "poisoned_fits": self.poisoned_fits,
                "cache": None if self.cache is None
                else self.cache.snapshot()}

    def restore_state(self, st: Dict) -> None:
        dev = lambda t: jax.tree_util.tree_map(jnp.asarray, t)
        self.params = dev(st["params"])
        self.opt = dev(st["opt"])
        self.runs_seen = int(st["runs_seen"])
        self._fit_calls = int(st["fit_calls"])
        self.nonfinite_steps = int(st["nonfinite_steps"])
        self.last_skipped_steps = int(st["last_skipped_steps"])
        self.poisoned_fits = int(st["poisoned_fits"])
        self.cache = None if st["cache"] is None \
            else TrainingCache.from_snapshot(st["cache"])

    def predict(self, graphs: Sequence[ComponentGraph]) -> np.ndarray:
        """Per-component total-runtime predictions (seconds)."""
        from repro.core.graph import empty_graph
        n = len(graphs)
        padded = list(graphs) + [empty_graph()] * (pow2_bucket(n) - n)
        batch = {k: jnp.asarray(v) for k, v in stack_graphs(padded).items()}
        return np.asarray(
            enel_model.predict_total_runtime(self.params, batch))[:n]

    def predict_stacked(self, batch: Dict[str, np.ndarray]) -> np.ndarray:
        """Totals for an already-stacked (B, N, ...) graph-array dict."""
        dev = {k: jnp.asarray(v) for k, v in batch.items()}
        return np.asarray(enel_model.predict_total_runtime(self.params, dev))

    def predict_sweep_device(self, template, deltas: Dict[str, np.ndarray],
                             use_kernel: bool = None) -> jax.Array:
        """Batched candidate-sweep predictions as a DEVICE (C, K) array.

        One device transfer + one jit call per decision: the template's
        (K, N, ...) base arrays and the small (C, K, ...) delta arrays are
        shipped as-is and evaluated via
        :func:`repro.core.model.sweep_per_component` with the propagation
        depth lowered to the template DAG's actual depth.  No host sync —
        callers reduce/pick on device and fetch once.
        """
        levels = min(enel_model.MAX_LEVELS, max(1, template.levels))
        return enel_model.sweep_per_component(
            self.params,
            {k: jnp.asarray(v) for k, v in template.base.items()},
            jnp.asarray(template.h_onehot),
            {k: jnp.asarray(np.asarray(v)) for k, v in deltas.items()},
            use_kernel=use_kernel, levels=levels)

    def predict_sweep(self, template, deltas: Dict[str, np.ndarray],
                      use_kernel: bool = None) -> np.ndarray:
        """Host (C, K) sweep predictions (reference/tests; one transfer)."""
        n_cand, n_rem = deltas["a_raw"].shape[:2]
        per = self.predict_sweep_device(template, deltas, use_kernel)
        return np.asarray(per)[:n_cand, :n_rem]


def _install_counter_properties():
    """Registry-backed guard counters behind the original attribute API."""
    def make(attr):
        def fget(self):
            return int(self._obs_counters[attr].value)

        def fset(self, value):
            self._obs_counters[attr].set(value)
        return property(fget, fset)

    for attr in _TRAINER_COUNTERS:
        setattr(EnelTrainer, attr, make(attr))


_install_counter_properties()
