"""Ellis baseline [Thamsen et al., CloudCom'17] (paper §V comparison).

Ellis fits a *new set of specialized models per run* — one scale-out model
per job component — estimates progress from completed components, and
rescales to the smallest scale-out whose predicted remaining runtime meets
the target.  Contrast: Enel uses ONE reusable context-aware graph model.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bell import BellModel


class EllisScaler:
    def __init__(self, scaleout_range: Tuple[int, int],
                 rescale_overhead: float = 5.0, candidate_stride: int = 1):
        self.range = scaleout_range
        self.rescale_overhead = rescale_overhead
        self.candidate_stride = max(1, candidate_stride)
        # history[component_idx] -> list of (scaleout, runtime)
        self.history: Dict[int, List[Tuple[float, float]]] = defaultdict(list)
        self.models: Dict[int, BellModel] = {}

    # -------------------------------------------------------------- training
    def observe_component(self, comp_idx: int, scaleout: float,
                          runtime: float) -> None:
        self.history[comp_idx].append((scaleout, runtime))

    def refit(self) -> None:
        """Per-run refit of every specialized component model."""
        self.models = {}
        for comp_idx, pairs in self.history.items():
            if len(pairs) >= 2:
                s = np.array([p[0] for p in pairs])
                t = np.array([p[1] for p in pairs])
                self.models[comp_idx] = BellModel().fit(s, t)

    # ------------------------------------------------------------- inference
    def predict_component(self, comp_idx: int, scaleout: float) -> float:
        m = self.models.get(comp_idx)
        if m is not None:
            return float(m.predict(scaleout)[0])
        pairs = self.history.get(comp_idx)
        if pairs:
            return float(np.mean([p[1] for p in pairs]))
        # fall back to the mean over all known components
        all_t = [t for ps in self.history.values() for (_, t) in ps]
        return float(np.mean(all_t)) if all_t else 0.0

    def predict_remaining(self, next_comp: int, n_components: int,
                          scaleout: float) -> float:
        return sum(self.predict_component(c, scaleout)
                   for c in range(next_comp, n_components))

    def recommend(self, *, next_comp: int, n_components: int, elapsed: float,
                  current_scaleout: int, target_runtime: float
                  ) -> Tuple[int, float]:
        """Smallest scale-out meeting the target; (scaleout, predicted_total)."""
        lo, hi = self.range
        best_s, best_total = current_scaleout, None
        feasible: List[Tuple[int, float]] = []
        cands = sorted(set(range(lo, hi + 1, self.candidate_stride))
                       | {hi, current_scaleout})
        for s in [c for c in cands if lo <= c <= hi]:
            overhead = self.rescale_overhead if s != current_scaleout else 0.0
            total = elapsed + overhead + self.predict_remaining(
                next_comp, n_components, s)
            if best_total is None or total < best_total:
                best_s, best_total = s, total
            if total <= target_runtime:
                feasible.append((s, total))
        if feasible:
            return feasible[0][0], feasible[0][1]
        return best_s, best_total if best_total is not None else elapsed
