"""Whole-campaign-on-device: sim step + decision sweep + resident fit fused
into ONE ``lax.scan`` over campaign steps.

The live fleet path (``FleetCampaign.adaptive_campaign``) interleaves host
python between every device dispatch: one sim-step jit per component round,
one sweep jit per decision round, one Adam jit per run, plus host graph
building, ring appends and bookkeeping in between.  This module compiles the
ENTIRE campaign — R runs x C components of J concurrent jobs — into a single
scanned jit per bucket-ladder rung:

* step ``t`` maps to (run ``t // C``, component ``t % C``);
* (a) one :func:`~repro.sim.engine._step_kernel_impl` component step against
  pre-drawn per-run input blocks (:meth:`BatchedClusterSim.
  campaign_run_blocks` consumes the SAME host RNG stream as the stepped
  path, so the noise/straggler/kill draws are bit-identical);
* (b) the observed component's ring row is built on device from frozen
  context tables and appended to the resident training ring as a pure carry
  update (:func:`~repro.core.graph.ring_append`);
* (c) on decision boundaries, the bucketed candidate sweep + on-device
  compliant pick runs via the SAME :func:`~repro.core.service.
  sweep_eval_one` ops the fleet service dispatches, with the
  :func:`~repro.core.fallback.fallback_pick` guardrail and the
  non-finite reduce folded into the scan (pure ops, no host round-trip);
* (d) at each run boundary, the paper's retrain cadence runs K resident
  Adam steps (:func:`~repro.core.training._adam_run_resident_impl`) from
  the ring under ``lax.cond`` — scratch reinit every ``retrain_every``-th
  run, fine-tune otherwise — and ``nan_fit`` chaos poisons params in-scan.

The host materializes traces ONCE at campaign end.  ``run_stepped`` drives
the identical step body through a python loop (one jit call per step) — the
parity contract ``run_fused == run_stepped`` is bit-exact and CI-tested.

Documented deviations from the LIVE host path (``adaptive_campaign``) —
the fused campaign is a faithful but not bit-identical twin:

* node contexts are FROZEN at plan time (``frozen_context_tables``:
  ``drop_versions=False``, ``attempt=0``) — the live encoder consumes RNG
  per observation for software-version dropout and bumps the attempt
  counter on failures;
* the candidate grid is the fixed ``range(lo, hi+1, stride) | {hi}`` —
  the live grid also splices in the current scale-out when off-stride;
* historical H-summary tables are frozen at plan time — the live
  ``hist_summaries`` grow intra-campaign, so live H nodes drift as runs
  accumulate;
* P-summary context/metrics are f32 device means (live: numpy means cast
  to f32 — identical op order for <= 5 stages, but not guaranteed bitwise);
* the per-run fit fires at the LAST component index of the longest job for
  every job, and fine-tune batches are padded to one uniform
  ``pow2_bucket(c_max)`` row count (live: per-job ``pow2_bucket(n_j)``,
  which changes the per-step dropout RNG shapes for shorter jobs);
* only ``nan_fit`` chaos is supported in-scan (``nan_graphs_every`` /
  ``cache_corrupt_every`` mutate host caches mid-run); the service-layer
  retry/breaker/shed envelope does not exist here — the in-scan guardrail
  is the isfinite reduce + fallback clamp.

None of these affect the fused==stepped contract, which shares every table
and every op; ``tests/test_fused_campaign.py`` additionally grounds the
fused kernel against ``BatchedClusterSim.run_full`` by replaying the fused
z-schedule (bit-exact stage runtimes/clocks).
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fallback import fallback_pick
from repro.core.graph import (CAND_LADDER, COMP_LADDER, EDGE_LADDER,
                              LEVEL_LADDER, N_METRICS, CTX_DIM, ladder_bucket,
                              historical_summaries_batch, pow2_bucket,
                              propagation_depth, ring_append)
from repro.core.model import (graph_prop_kernel_enabled, init_enel,
                              record_trace)
from repro.core.service import sweep_eval_one
from repro.core.training import _adam_run_resident_impl, _round_steps

# engine <-> dataflow import cycle: initialize the dataflow package first so
# repro.sim.engine's simulator import finds it loaded (same order the fleet
# entry points use)
import repro.dataflow  # noqa: F401  (import-order side effect only)
from repro.sim.engine import (_nc, _step_kernel_impl, _O_CLK, _O_FAILED,
                              _O_MET, _O_RT, BatchedClusterSim)

N_ROW = 8          # ring-row / sweep node slots (stages + P + H, bucketed)


class PlanStatic(NamedTuple):
    """Hashable static config of one fused campaign (jit static argnum 0).

    One compile per distinct PlanStatic — the compile count of a campaign
    is bounded by the bucket-ladder rungs these fields can take, asserted
    in CI via ``model.TRACE_COUNTS["fused_campaign"]``.
    """
    c_max: int           # component steps per run (longest job)
    s_max: int           # stage rows per component step (engine S)
    lo: int              # scale-out grid origin (SCALEOUT_RANGE[0])
    tune_rows: int       # fine-tune batch rows: pow2_bucket(c_max)
    scratch_steps: int   # _round_steps(steps)
    tune_steps: int      # _round_steps(fine_tune_steps)
    retrain_every: int
    use_kernel: bool     # graph_prop Pallas kernel toggle (frozen at plan)
    levels: int          # bucketed propagation depth for the sweep
    telemetry: bool = False  # in-scan obs block (ENEL_OBS; default at
    #                          build_plan). False compiles the exact
    #                          pre-observability program: same carry, same
    #                          ys, same jaxpr — the ENEL_OBS=0 bit-exactness
    #                          and zero-extra-traces guarantees.


class CampaignPlan:
    """Everything one fused campaign needs: static shapes, device tables,
    the initial carry, and the host-side materialization tables."""

    def __init__(self, static: PlanStatic, dev: Dict[str, Any],
                 init: Dict[str, Any], host: Dict[str, Any]):
        self.static = static
        self.dev = dev
        self.init = init
        self.host = host

    @property
    def n_jobs(self) -> int:
        return int(self.dev["inject"].shape[0])

    @property
    def n_runs(self) -> int:
        return int(self.dev["blocks"].shape[0])

    @property
    def n_steps(self) -> int:
        return self.n_runs * self.static.c_max


# =========================================================================
# the fused step body: ONE component step of the whole fleet
# =========================================================================

def _step(st: PlanStatic, dev, carry, t):
    """(carry, t) -> (carry', ys): component ``t % c_max`` of run
    ``t // c_max`` for every job — sim step, ring append, decision sweep
    (on decision boundaries) and the per-run fit (on run boundaries), all
    as pure ops so ``lax.scan`` fuses the whole campaign."""
    record_trace("fused_campaign")
    f32 = jnp.float32
    r = t // st.c_max
    k = t - r * st.c_max
    J = dev["inject"].shape[0]
    ji = jnp.arange(J)
    nsg = dev["obs_ctx"].shape[3]

    def zi(s):
        return jnp.clip(s.astype(jnp.int32) - st.lo, 0, nsg - 1)

    at_start = k == 0
    clock = jnp.where(at_start, f32(0.0), carry["clock"])
    s_prev = jnp.where(at_start, dev["s0"], carry["s_prev"])
    s_cur = jnp.where(at_start, dev["s0"], carry["s_cur"])
    a, z = s_prev, s_cur
    comp_ok = dev["comp_valid"][k]                       # (J,)

    # ---------------------------------------------- (a) fleet sim step
    # device twin of tables.overhead_f32 (anti-FMA guarded like the engine)
    d = jnp.abs(z - a)
    ov = jnp.where(a == z, f32(0.0), f32(4.0) + _nc(f32(0.35) * d))
    ctrl = jnp.stack([clock, carry["interf"], a, z, dev["inject"],
                      dev["n_stage_f"][k], ov, dev["cursor_f"][k]], axis=-1)
    state, outs = _step_kernel_impl(
        dev["blocks"][r], ctrl, st.s_max, dev["kills"][r], dev["burst"],
        dev["preempt"], dev["iscale2"], dev["mem_tab"], dev["shuf_tab"])
    clock = state[:, 0]                                  # pass-through when
    interf = state[:, 1]                                 # comp invalid (n=0)

    # ------------------------------------- (b) observed ring row, on device
    g = dev["cls"]
    h = dev["hcls"]
    rmask = dev["row_mask"][g, k]                        # (J, N_ROW)
    rsum = dev["row_summ"][g, k]
    radj = dev["row_adj"][g, k]                          # (J, N_ROW, N_ROW)
    rsi = dev["row_stage_idx"][g, k]                     # (J, N_ROW) i32
    rst = dev["row_is_stage"][g, k]
    rs0 = rst & (rsi == 0)
    rp = dev["row_is_p"][g, k]
    rh = dev["row_is_h"][g, k]

    pm, pa, pz = carry["p_met"], carry["p_a"], carry["p_z"]
    km1 = jnp.maximum(k - 1, 0)
    ctx_k = dev["obs_ctx"][g, k]                         # (J, S, NS, CTX)
    ctx_kz = ctx_k[ji[:, None], rsi, zi(z)[:, None]]     # (J, N_ROW, CTX)
    p_ctx_old = dev["p_ctx"][g, km1, zi(pz)]             # (J, CTX)
    h_ctx = dev["hob_ctx"][h, k, zi(z)]                  # (J, CTX)
    h_met = dev["hob_met"][h, k, zi(z)]                  # (J, N_METRICS)
    h_val = dev["hob_val"][h, k, zi(z)]                  # (J,)
    h_a = dev["hob_start"][h, k, zi(z)]
    h_b = dev["hob_end"][h, k, zi(z)]

    met_js = jnp.swapaxes(outs[:, :, _O_MET], 0, 1)      # (J, S, 5)
    rt_js = jnp.swapaxes(outs[:, :, _O_RT], 0, 1)        # (J, S)
    row_met = met_js[ji[:, None], rsi]                   # (J, N_ROW, 5)
    row_rt = rt_js[ji[:, None], rsi]                     # (J, N_ROW)

    a2, z2 = a[:, None], z[:, None]
    rescale0 = rs0 & (a2 != z2)
    w3 = lambda m: m[..., None]
    row = {
        "context": (jnp.where(w3(rst), ctx_kz, 0.0)
                    + jnp.where(w3(rp), p_ctx_old[:, None, :], 0.0)
                    + jnp.where(w3(rh), h_ctx[:, None, :], 0.0)),
        "metrics": (jnp.where(w3(rst), row_met, 0.0)
                    + jnp.where(w3(rp), pm[:, None, :], 0.0)
                    + jnp.where(w3(rh), h_met[:, None, :], 0.0)),
        "metrics_valid": rst | rp | (rh & h_val[:, None]),
        "a_raw": jnp.where(rs0, a2, jnp.where(rst, z2, jnp.where(
            rp, pa[:, None], jnp.where(rh, h_a[:, None], 1.0)))),
        "z_raw": jnp.where(rst, z2, jnp.where(
            rp, pz[:, None], jnp.where(rh, h_b[:, None], 1.0))),
        "r": jnp.where(rescale0, f32(0.8), f32(1.0)),
        "runtime": jnp.where(rst, row_rt, 0.0),
        "runtime_valid": rst,
        "overhead": jnp.where(rescale0, ov[:, None], 0.0),
        "overhead_valid": rescale0,
        "adj": radj,
        "mask": rmask,
        "is_summary": rsum,
    }

    ring = carry["ring"]
    cap = ring["slot_ok"].shape[1]

    def _append(bufs, row_j, pos_j, ok_j, slot_ok_j):
        old = jax.tree_util.tree_map(lambda b: b[pos_j], bufs)
        sel = jax.tree_util.tree_map(
            lambda nv, ovv: jnp.where(ok_j, nv.astype(ovv.dtype), ovv),
            row_j, old)
        bufs = ring_append(bufs, sel, pos_j)
        slot_ok_j = slot_ok_j.at[pos_j].set(
            jnp.where(ok_j, True, slot_ok_j[pos_j]))
        return bufs, slot_ok_j

    buffers, slot_ok = jax.vmap(_append)(
        ring["buffers"], row, ring["pos"], comp_ok, ring["slot_ok"])
    inc = comp_ok.astype(jnp.int32)
    pos = (ring["pos"] + inc) % cap
    count = jnp.minimum(ring["count"] + inc, cap)

    # fresh P(k) summary (current_summary for this boundary's decision)
    nst = dev["n_stage_f"][k].astype(jnp.int32)
    sv = jnp.arange(st.s_max)[None, :] < nst[:, None]    # (J, S)
    pm_new = (jnp.sum(jnp.where(sv[..., None], met_js, 0.0), axis=1)
              / jnp.maximum(nst, 1)[:, None].astype(f32))
    pm = jnp.where(comp_ok[:, None], pm_new, pm)
    pa = jnp.where(comp_ok, a, pa)
    pz = jnp.where(comp_ok, z, pz)

    # ------------------------------------ (c) decision sweep + guardrails
    decide = dev["decide_tab"][k]                        # (J,)
    cand = dev["cand"]
    cand_valid = dev["cand_valid"]
    n_cand = cand.shape[0]

    def _decide_one(p_j, g_j, h_j, s_j, el_j, tg_j, nj_j, pm_j, pa_j, pz_j):
        stg = dev["sw_is_stage"][g_j]                    # (K, N)
        sidx = dev["sw_stage_idx"][g_j]
        isp = dev["sw_is_p"][g_j]
        ish = dev["sw_is_h"][g_j]
        comp_of = dev["sw_comp"]                         # (K,) = ki + 1
        vk = (comp_of > k) & (comp_of < nj_j)            # remaining comps
        isn = comp_of == (k + 1)
        mask_j = dev["sw_mask0"][g_j] & vk[:, None] \
            & (~isp | isn[:, None])
        zis = jnp.clip(s_j.astype(jnp.int32) - st.lo, 0, nsg - 1)
        ctx_z = dev["obs_ctx"][g_j, :, :, zis]           # (C_max, S, CTX)
        cc = jnp.clip(comp_of, 0, dev["obs_ctx"].shape[1] - 1)
        ctx_st = ctx_z[cc[:, None], sidx]                # (K, N, CTX)
        pzi = jnp.clip(pz_j.astype(jnp.int32) - st.lo, 0, nsg - 1)
        pctx = dev["p_ctx"][g_j, k, pzi]                 # (CTX,)
        base = {
            "context": (jnp.where(stg[..., None], ctx_st, 0.0)
                        + jnp.where(isp[..., None],
                                    pctx[None, None, :], 0.0)),
            "metrics": jnp.where(isp[..., None], pm_j[None, None, :], 0.0),
            "adj": dev["sw_adj"][g_j],
            "mask": mask_j,
            "is_summary": dev["sw_summ"][g_j],
        }
        zsel = jnp.broadcast_to(cand[:, None], (n_cand, stg.shape[0]))
        asel = jnp.where(isn[None, :], s_j, zsel)
        st0 = stg & (sidx == 0)
        a3, z3 = asel[:, :, None], zsel[:, :, None]
        h_a3 = dev["hsw_start"][h_j][..., None]          # (C, K, 1)
        h_b3 = dev["hsw_end"][h_j][..., None]
        hv3 = dev["hsw_val"][h_j][..., None]
        deltas = {
            "a_raw": jnp.where(st0[None], a3, jnp.where(
                stg[None], z3, jnp.where(isp[None], pa_j, jnp.where(
                    ish[None], h_a3, 1.0)))).astype(f32),
            "z_raw": jnp.where(stg[None], z3, jnp.where(
                isp[None], pz_j, jnp.where(ish[None], h_b3, 1.0))
            ).astype(f32),
            "r": jnp.where(stg[None] & (a3 != z3), f32(0.8), f32(1.0)),
            "metrics_valid": (isp[None] | (ish[None] & hv3))
            & mask_j[None],
            "h_context": dev["hsw_ctx"][h_j],            # (C, K, CTX)
            "h_metrics": dev["hsw_met"][h_j],
        }
        ed = dev["sw_edge_dst"][g_j]                     # (K, E)
        es = dev["sw_edge_src"][g_j]
        ev = (dev["sw_edge_val"][g_j]
              & jnp.take_along_axis(mask_j, ed, axis=1)
              & jnp.take_along_axis(mask_j, es, axis=1))
        idx, totals, _, ok = sweep_eval_one(
            p_j, base, dev["sw_oh"][g_j], deltas, ed, es, ev, cand,
            cand_valid, el_j, tg_j, st.levels)
        fb = fallback_pick(cand, cand_valid, totals, s_j, el_j, tg_j)
        return cand[jnp.where(ok, idx, fb)], ok

    def _run_sweep(_):
        return jax.vmap(_decide_one)(
            carry["params"], g, h, s_cur, clock, dev["target"],
            dev["n_comp"], pm, pa, pz)

    def _no_sweep(_):
        return s_cur, jnp.ones(J, bool)

    s_new, dec_ok = jax.lax.cond(dev["any_decide"][k], _run_sweep,
                                 _no_sweep, None)
    fb_used = decide & ~dec_ok
    nonfin = decide & ~jnp.isfinite(s_new)
    s_next = jnp.where(decide, s_new, s_cur)
    # belt-and-braces: a non-finite decision must NEVER leave the scan
    s_next = jnp.where(jnp.isfinite(s_next), s_next, s_cur)

    # --------------------------------------- (d) per-run resident fit
    params, opt, fcalls = carry["params"], carry["opt"], carry["fit_calls"]
    is_last = k == st.c_max - 1

    def _run_adam(p, o, batch, w, steps):
        keys = jax.vmap(jax.random.fold_in)(dev["base_key"], fcalls)

        def one(pj, oj, bj, wj, kj, lr_j):
            return _adam_run_resident_impl(
                pj, oj, bj, wj, kj, lr_j, dev["dropout_p"], steps,
                st.use_kernel)

        return jax.vmap(one)(p, o, batch, w, keys, dev["lr"])

    def _fit_scratch(_):
        p0 = dev["init_params"]
        o0 = (jax.tree_util.tree_map(jnp.zeros_like, p0),
              jax.tree_util.tree_map(jnp.zeros_like, p0),
              jnp.zeros(J, jnp.int32))
        w = ((jnp.arange(cap)[None, :] < count[:, None])
             & slot_ok).astype(f32)
        return _run_adam(p0, o0, buffers, w, st.scratch_steps)

    def _fit_tune(_):
        rows = jnp.arange(st.tune_rows)[None, :]
        idx = (pos[:, None] - dev["n_comp"][:, None] + rows) % cap
        live = rows < dev["n_comp"][:, None]
        idx = jnp.where(live, idx, 0)
        batch = jax.tree_util.tree_map(
            lambda b: b[ji[:, None], idx], buffers)
        w = (live & slot_ok[ji[:, None], idx]).astype(f32)
        return _run_adam(params, opt, batch, w, st.tune_steps)

    def _do_fit(_):
        return jax.lax.cond(dev["scratch_at"][r], _fit_scratch, _fit_tune,
                            None)

    def _no_fit(_):
        return params, opt, jnp.zeros(J, f32), jnp.zeros(J, jnp.int32)

    params, opt, fit_loss, fit_skip = jax.lax.cond(is_last, _do_fit,
                                                   _no_fit, None)
    fcalls = jnp.where(is_last, fcalls + 1, fcalls)

    # nan_fit chaos fires right after the fit, exactly like the live hook
    pmask = dev["poison_at"][r] & is_last
    params = jax.tree_util.tree_map(
        lambda p: jnp.where(pmask.reshape((-1,) + (1,) * (p.ndim - 1)),
                            jnp.nan, p), params)

    new_carry = {
        "clock": clock, "interf": interf,
        "s_prev": s_cur, "s_cur": s_next,
        "p_met": pm, "p_a": pa, "p_z": pz,
        "ring": {"buffers": buffers, "pos": pos, "count": count,
                 "slot_ok": slot_ok},
        "params": params, "opt": opt, "fit_calls": fcalls,
        "fallbacks": carry["fallbacks"] + fb_used.astype(jnp.int32),
        "nonfinite": carry["nonfinite"] + nonfin.astype(jnp.int32),
    }
    ys = {
        "clock": clock, "interf": interf, "a": a, "z": z, "s_next": s_next,
        "decided": decide, "dec_ok": dec_ok, "fallback": fb_used,
        "nonfinite": nonfin, "fit_loss": fit_loss, "fit_skipped": fit_skip,
        "rt": outs[:, :, _O_RT], "failed": outs[:, :, _O_FAILED],
        "stage_clk": outs[:, :, _O_CLK],
    }

    if st.telemetry:
        # ---------------------------- in-scan flight-recorder telemetry:
        # a compact per-step block widening the carry (decision-gap step
        # deltas as the pick-latency proxy, per-run fallback/non-finite/
        # fit-skip counts, per-run compliance margin) materialized into ys
        # at run boundaries and replayed into the recorder at write-back
        # (see ``replay_spans``).  Pure observation: nothing below feeds
        # back into the decision or training ops above.
        i32 = jnp.int32
        tel = carry["tel"]
        gap_valid = decide & (tel["last_dec_t"] >= 0)
        gap = jnp.where(gap_valid, t - tel["last_dec_t"], 0).astype(i32)
        last_dec_t = jnp.where(decide, t, tel["last_dec_t"]).astype(i32)
        run_fb = tel["run_fallbacks"] + fb_used.astype(i32)
        run_nf = tel["run_nonfinite"] + nonfin.astype(i32)
        run_fs = tel["run_fit_skip"] + fit_skip
        zero = jnp.zeros_like(run_fb)
        new_carry["tel"] = {
            "last_dec_t": last_dec_t,
            "run_fallbacks": jnp.where(is_last, zero, run_fb),
            "run_nonfinite": jnp.where(is_last, zero, run_nf),
            "run_fit_skip": jnp.where(is_last, zero, run_fs),
            "gap_sum": tel["gap_sum"] + gap,
            "gap_n": tel["gap_n"] + gap_valid.astype(i32),
        }
        ys.update(
            tel_dec_gap=gap,
            tel_margin=jnp.where(is_last, dev["target"] - clock, f32(0.0)),
            tel_run_fallbacks=jnp.where(is_last, run_fb, zero),
            tel_run_nonfinite=jnp.where(is_last, run_nf, zero),
            tel_run_fit_skip=jnp.where(is_last, run_fs, zero),
        )
    return new_carry, ys


def _scan_impl(st, dev, carry, ts):
    return jax.lax.scan(lambda c, t: _step(st, dev, c, t), carry, ts)


_SCAN_JIT = jax.jit(_scan_impl, static_argnums=(0,))
_STEP_JIT = jax.jit(_step, static_argnums=(0,))


# =========================================================================
# drivers
# =========================================================================

def init_carry(plan: CampaignPlan):
    return jax.tree_util.tree_map(jnp.asarray, plan.init)


def run_fused(plan: CampaignPlan, carry=None, start: int = 0,
              stop: Optional[int] = None):
    """Scan steps [start, stop) in ONE dispatch -> (final carry, ys)."""
    if carry is None:
        carry = init_carry(plan)
    if stop is None:
        stop = plan.n_steps
    ts = jnp.arange(start, stop, dtype=jnp.int32)
    return _SCAN_JIT(plan.static, plan.dev, carry, ts)


def run_stepped(plan: CampaignPlan, carry=None, start: int = 0,
                stop: Optional[int] = None):
    """Python loop over the SAME jitted step body (parity comparator /
    incremental driver); returns ys stacked exactly like the scan's."""
    if carry is None:
        carry = init_carry(plan)
    if stop is None:
        stop = plan.n_steps
    ys_steps = []
    for t in range(start, stop):
        carry, y = _STEP_JIT(plan.static, plan.dev, carry, jnp.int32(t))
        ys_steps.append(y)
    ys = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ys_steps)
    return carry, ys


def carry_to_host(carry) -> Dict[str, Any]:
    """Picklable numpy copy of a scan carry (mid-campaign checkpoint)."""
    return jax.tree_util.tree_map(np.asarray, carry)


def carry_from_host(carry) -> Dict[str, Any]:
    return jax.tree_util.tree_map(jnp.asarray, carry)


def replay_spans(plan: CampaignPlan, ys, start: int = 0,
                 recorder=None) -> int:
    """Replay a fused-campaign ys block into the flight recorder.

    A pure function of ``(plan, ys)``: the span stream depends only on the
    materialized scan outputs, so ``run_fused`` and ``run_stepped`` of the
    same plan replay to IDENTICAL ``(kind, attrs)`` streams (parity-tested
    in ``tests/test_obs.py``).  Timestamps are the *logical* step index
    (not wall time).  Returns the number of spans emitted.

    Span kinds mirror the live stepped path where an in-scan analogue
    exists: ``decision.pick`` per decided job (with the step-delta pick
    latency proxy), ``decision.fallback`` for guardrail-clamped picks,
    ``fit`` at run boundaries and ``run.end`` with the per-run compliance
    margin + fallback/non-finite/fit-skip counts from the in-scan
    telemetry block (plans built with ``telemetry=False`` have no tel
    arrays, so only the base decision/fit spans replay).
    """
    from repro import obs as _obs
    if recorder is None:
        recorder = _obs.recorder()
    if not _obs.enabled():
        return 0
    h = plan.host
    ysn = {k: np.asarray(v) for k, v in ys.items()}
    c_max = plan.static.c_max
    names = h["job_names"]
    scratch_at = h.get("scratch_at")
    n = 0
    for i in range(ysn["decided"].shape[0]):
        t = start + i
        r, k = divmod(t, c_max)
        decided = ysn["decided"][i]
        for j, name in enumerate(names):
            if decided[j]:
                attrs = dict(driver="fused", job=name, run=r, comp=k,
                             scaleout=int(ysn["s_next"][i, j]),
                             fallback=bool(ysn["fallback"][i, j]))
                if "tel_dec_gap" in ysn:
                    attrs["gap_steps"] = int(ysn["tel_dec_gap"][i, j])
                recorder.emit("decision.pick", _ts=float(t), **attrs)
                n += 1
                if attrs["fallback"]:
                    recorder.emit(
                        "decision.fallback", _ts=float(t), driver="fused",
                        job=name, run=r, comp=k, cause="guardrail",
                        nonfinite=bool(ysn["nonfinite"][i, j]))
                    n += 1
        if k == c_max - 1:                      # run boundary: fit + run.end
            scratch = bool(scratch_at[r]) if scratch_at is not None and \
                r < len(scratch_at) else False
            for j, name in enumerate(names):
                recorder.emit(
                    "fit", _ts=float(t), driver="fused", job=name, run=r,
                    mode="scratch" if scratch else "tune",
                    skipped=int(ysn["fit_skipped"][i, j]),
                    loss=round(float(ysn["fit_loss"][i, j]), 6))
                n += 1
                if "tel_margin" in ysn:
                    recorder.emit(
                        "run.end", _ts=float(t), driver="fused", job=name,
                        run=r, clock=round(float(ysn["clock"][i, j]), 4),
                        margin=round(float(ysn["tel_margin"][i, j]), 4),
                        fallbacks=int(ysn["tel_run_fallbacks"][i, j]),
                        nonfinite=int(ysn["tel_run_nonfinite"][i, j]),
                        fit_skipped=int(ysn["tel_run_fit_skip"][i, j]))
                    n += 1
    return n


# =========================================================================
# plan construction (host side, once per campaign)
# =========================================================================

def _class_tables(exp, c_max: int, s_max: int, k_pad: int,
                  e_pad: int) -> Dict[str, np.ndarray]:
    """Structural tables shared by every experiment of one job class:
    frozen observation contexts, ring-row node layout and the sweep's
    candidate-invariant graph structure (fixed slot layout: stages 0..n-1,
    P at n — masked unless next component — and H at n+1; masked slots
    contribute exact zeros in the sparse sweep, so the fixed layout is
    functionally identical to the live path's compaction)."""
    from repro.dataflow.runner import frozen_context_tables
    job = exp.job
    ctx, n_stages = frozen_context_tables(exp.encoder, job)
    n_comp, s_loc, ns = ctx.shape[0], ctx.shape[1], ctx.shape[2]
    obs = np.zeros((c_max, s_max, ns, CTX_DIM), np.float32)
    obs[:n_comp, :s_loc] = ctx
    nst = np.zeros(c_max, np.int32)
    nst[:n_comp] = n_stages
    p_ctx = np.zeros((c_max, ns, CTX_DIM), np.float32)
    for c in range(n_comp):
        p_ctx[c] = ctx[c, :n_stages[c]].mean(axis=0)

    row_mask = np.zeros((c_max, N_ROW), bool)
    row_summ = np.zeros((c_max, N_ROW), bool)
    row_st = np.zeros((c_max, N_ROW), bool)
    row_p = np.zeros((c_max, N_ROW), bool)
    row_h = np.zeros((c_max, N_ROW), bool)
    row_si = np.zeros((c_max, N_ROW), np.int32)
    row_adj = np.zeros((c_max, N_ROW, N_ROW), bool)
    for c in range(n_comp):
        n = int(n_stages[c])
        row_mask[c, :n] = True
        row_st[c, :n] = True
        row_si[c, :n] = np.arange(n)
        for i in range(n - 1):
            row_adj[c, i + 1, i] = True
        if c > 0:                     # P(k-1) and H(k-1) predecessor slots
            row_mask[c, n:n + 2] = True
            row_summ[c, n:n + 2] = True
            row_p[c, n] = True
            row_h[c, n + 1] = True
            row_adj[c, 0, n] = True
            row_adj[c, 0, n + 1] = True

    sw_mask0 = np.zeros((k_pad, N_ROW), bool)
    sw_summ = np.zeros((k_pad, N_ROW), bool)
    sw_st = np.zeros((k_pad, N_ROW), bool)
    sw_p = np.zeros((k_pad, N_ROW), bool)
    sw_h = np.zeros((k_pad, N_ROW), bool)
    sw_si = np.zeros((k_pad, N_ROW), np.int32)
    sw_oh = np.zeros((k_pad, N_ROW), np.float32)
    sw_adj = np.zeros((k_pad, N_ROW, N_ROW), bool)
    sw_ed = np.zeros((k_pad, e_pad), np.int32)
    sw_es = np.zeros((k_pad, e_pad), np.int32)
    sw_ev = np.zeros((k_pad, e_pad), bool)
    depth = 1
    for ki in range(k_pad):
        c = ki + 1
        if c >= n_comp:
            continue
        n = int(n_stages[c])
        assert n + 2 <= N_ROW, "sweep slots overflow the node bucket"
        sw_mask0[ki, :n + 2] = True
        sw_st[ki, :n] = True
        sw_si[ki, :n] = np.arange(n)
        sw_summ[ki, n:n + 2] = True
        sw_p[ki, n] = True
        sw_h[ki, n + 1] = True
        sw_oh[ki, n + 1] = 1.0
        adj = np.zeros((N_ROW, N_ROW), bool)
        for i in range(n - 1):
            adj[i + 1, i] = True
        adj[0, n] = True
        adj[0, n + 1] = True
        sw_adj[ki] = adj
        pairs = np.argwhere(adj)               # (m, 2): [dst, src], the
        m = len(pairs)                         # live sweep_edge_list order
        assert m <= e_pad, "edge bucket overflow"
        sw_ed[ki, :m] = pairs[:, 0]
        sw_es[ki, :m] = pairs[:, 1]
        sw_ev[ki, :m] = True
        depth = max(depth, propagation_depth(adj, sw_mask0[ki]))
    return {
        "obs_ctx": obs, "p_ctx": p_ctx, "n_stage": nst,
        "row_mask": row_mask, "row_summ": row_summ, "row_is_stage": row_st,
        "row_is_p": row_p, "row_is_h": row_h, "row_stage_idx": row_si,
        "row_adj": row_adj,
        "sw_mask0": sw_mask0, "sw_summ": sw_summ, "sw_is_stage": sw_st,
        "sw_is_p": sw_p, "sw_is_h": sw_h, "sw_stage_idx": sw_si,
        "sw_oh": sw_oh, "sw_adj": sw_adj, "sw_edge_dst": sw_ed,
        "sw_edge_src": sw_es, "sw_edge_val": sw_ev,
        "depth": np.int32(depth),
    }


def _hist_tables(exp, c_max: int, k_pad: int, grid: np.ndarray,
                 cand: np.ndarray) -> Dict[str, np.ndarray]:
    """Frozen historical-summary tables: per component k, the H(k-1) node
    attributes at every grid scale-out (ring rows) and at every candidate
    (sweep deltas).  Matches the live ranking exactly at plan time; the
    live history keeps growing afterwards (documented deviation)."""
    beta = exp.enel.beta
    ns, c_pad = len(grid), len(cand)
    n_comp = exp.job.n_components
    hob_ctx = np.zeros((c_max, ns, CTX_DIM), np.float32)
    hob_met = np.zeros((c_max, ns, N_METRICS), np.float32)
    hob_val = np.zeros((c_max, ns), bool)
    hob_start = np.ones((c_max, ns), np.float32)
    hob_end = np.ones((c_max, ns), np.float32)
    for k in range(1, n_comp):
        hl = exp.enel.hist_summaries.get(k - 1, [])
        if not hl:
            raise ValueError(
                f"no history for component {k - 1} of {exp.job.name} — "
                "run profile() before building a fused campaign plan")
        hb = historical_summaries_batch(hl, grid, beta)
        hob_ctx[k] = hb["context"]
        hob_met[k] = hb["metrics"]
        hob_val[k] = hb["metrics_valid"]
        hob_start[k] = np.maximum(hb["start"], 1e-6)
        hob_end[k] = np.maximum(hb["end"], 1e-6)
    hsw_ctx = np.zeros((c_pad, k_pad, CTX_DIM), np.float32)
    hsw_met = np.zeros((c_pad, k_pad, N_METRICS), np.float32)
    hsw_val = np.zeros((c_pad, k_pad), bool)
    hsw_start = np.ones((c_pad, k_pad), np.float32)
    hsw_end = np.ones((c_pad, k_pad), np.float32)
    for ki in range(k_pad):
        c = ki + 1
        if c >= n_comp:
            continue
        hl = exp.enel.hist_summaries.get(c - 1, [])
        if not hl:
            raise ValueError(
                f"no history for component {c - 1} of {exp.job.name} — "
                "run profile() before building a fused campaign plan")
        hb = historical_summaries_batch(hl, cand, beta)
        hsw_ctx[:, ki] = hb["context"]
        hsw_met[:, ki] = hb["metrics"]
        hsw_val[:, ki] = hb["metrics_valid"]
        hsw_start[:, ki] = np.maximum(hb["start"], 1e-6)
        hsw_end[:, ki] = np.maximum(hb["end"], 1e-6)
    return {"hob_ctx": hob_ctx, "hob_met": hob_met, "hob_val": hob_val,
            "hob_start": hob_start, "hob_end": hob_end,
            "hsw_ctx": hsw_ctx, "hsw_met": hsw_met, "hsw_val": hsw_val,
            "hsw_start": hsw_start, "hsw_end": hsw_end}


def build_plan(experiments, n_runs: int, *, inject_failures: bool = False,
               retrain_every: int = 5, steps: int = 160,
               fine_tune_steps: int = 60,
               metric_dropout: float = 0.5,
               telemetry: Optional[bool] = None) -> CampaignPlan:
    """Compile a fused whole-campaign plan for ``n_runs`` adaptive runs of
    a profiled fleet sharing one :class:`BatchedClusterSim`.

    Consumes the backend's RNG streams exactly as ``n_runs`` stepped runs
    would (via :meth:`campaign_run_blocks`), so a fused campaign and a
    stepped campaign from the same seed state see identical draws.  Raises
    on configurations the in-scan path cannot honour (unprofiled jobs,
    host-side chaos families, capacity caps, non-uniform trainer cadence).
    """
    exps = list(experiments)
    if not exps:
        raise ValueError("empty fleet")
    backend = exps[0].backend
    if not isinstance(backend, BatchedClusterSim):
        raise TypeError("fused campaigns need the batched sim engine "
                        "(FleetCampaign(..., engine='batched'))")
    for i, e in enumerate(exps):
        if e.backend is not backend:
            raise ValueError("all experiments must share ONE backend")
        if e.sim_slot != i:
            raise ValueError("experiment order must match sim slots")
        if e.target is None:
            raise ValueError(f"{e.job.name}: profile() first")
        cache = e.trainer.cache
        if cache is None or cache.count == 0:
            raise ValueError(f"{e.job.name}: empty training ring")
        if cache.max_nodes != N_ROW:
            raise ValueError(f"ring rows have {cache.max_nodes} node "
                             f"slots, fused kernel needs {N_ROW}")
        if e.scale_cap is not None:
            raise ValueError("capacity caps are a host-path feature")
        if e.chaos is not None and (e.chaos.spec.nan_graphs_every
                                    or e.chaos.spec.cache_corrupt_every):
            raise ValueError("only nan_fit chaos runs in-scan; "
                             "nan_graphs/cache_corrupt mutate host caches")
    J = len(exps)
    lo, hi = exps[0].enel.range
    stride = exps[0].enel.candidate_stride
    cap = exps[0].trainer.cache.capacity
    runs_seen0 = exps[0].trainer.runs_seen
    for e in exps:
        if e.enel.range != (lo, hi) or \
                e.enel.candidate_stride != stride:
            raise ValueError("candidate grids must be uniform")
        if e.trainer.cache.capacity != cap:
            raise ValueError("ring capacities must be uniform")
        if e.trainer.runs_seen != runs_seen0:
            raise ValueError("trainer cadence must be uniform "
                             "(equal runs_seen)")

    grid_c = sorted(set(range(lo, hi + 1, stride)) | {hi})
    c_real = len(grid_c)
    c_pad = ladder_bucket(c_real, CAND_LADDER)
    cand = np.full(c_pad, grid_c[-1], np.float32)
    cand[:c_real] = grid_c
    cand_valid = np.zeros(c_pad, bool)
    cand_valid[:c_real] = True
    grid_all = np.arange(lo, hi + 1, dtype=np.float32)

    const = backend.fused_sim_constants()
    s_max = int(const["s_max"])
    c_max = max(e.job.n_components for e in exps)
    k_pad = ladder_bucket(max(c_max - 1, 1), COMP_LADDER)
    e_pad = ladder_bucket(s_max + 1, EDGE_LADDER)

    # ---- structural tables, deduplicated per job class
    cls_of: Dict[tuple, int] = {}
    classes: List[Dict[str, np.ndarray]] = []
    cls = np.zeros(J, np.int32)
    for i, e in enumerate(exps):
        key = (e.job.name, e.seed, e.job.n_components,
               tuple(len(e.job.stages(c))
                     for c in range(e.job.n_components)))
        if key not in cls_of:
            cls_of[key] = len(classes)
            classes.append(_class_tables(e, c_max, s_max, k_pad, e_pad))
        cls[i] = cls_of[key]
    depth = max(int(c["depth"]) for c in classes)
    levels = ladder_bucket(depth, LEVEL_LADDER)

    # ---- frozen history tables, deduplicated per (job, seed, progress)
    h_of: Dict[tuple, int] = {}
    hists: List[Dict[str, np.ndarray]] = []
    hcls = np.zeros(J, np.int32)
    for i, e in enumerate(exps):
        key = (e.job.name, e.seed, e._run_idx, e.trainer.runs_seen,
               tuple(len(e.enel.hist_summaries.get(c, []))
                     for c in range(e.job.n_components)))
        if key not in h_of:
            h_of[key] = len(hists)
            hists.append(_hist_tables(e, c_max, k_pad, grid_all, cand))
        hcls[i] = h_of[key]

    # ---- per-job schedule tables
    n_comp = np.array([e.job.n_components for e in exps], np.int32)
    comp_valid = np.zeros((c_max, J), bool)
    decide_tab = np.zeros((c_max, J), bool)
    n_stage_f = np.zeros((c_max, J), np.float32)
    cursor_f = np.zeros((c_max, J), np.float32)
    for i, e in enumerate(exps):
        nc = e.job.n_components
        comp_valid[:nc, i] = True
        for k in range(nc):
            decide_tab[k, i] = (k < nc - 1
                                and k % e.decision_interval == 0)
        tab = backend._slots[i].tables
        n_stage_f[:nc, i] = tab.n_stages
        cursor_f[:nc, i] = tab.comp_start
        cursor_f[nc:, i] = tab.total_stages
    any_decide = decide_tab.any(axis=1)

    # ---- fixed s0 (exact under method="enel": Ellis never refits during
    # adaptive runs, so its recommendation is constant across the campaign)
    s0 = np.zeros(J, np.float32)
    predicted = []
    for i, e in enumerate(exps):
        rec, p_hat = e.ellis.recommend(
            next_comp=0, n_components=e.job.n_components, elapsed=0.0,
            current_scaleout=lo, target_runtime=e.target)
        s0[i] = rec
        predicted.append(p_hat)
    inject = np.array(
        [float(bool(inject_failures) or e.scenario.inject_failures)
         for e in exps], np.float32)
    target = np.array([e.target for e in exps], np.float32)

    # ---- fit cadence / chaos schedules
    scratch_at = np.array(
        [((runs_seen0 + r + 1) % retrain_every) == 0
         for r in range(n_runs)], bool)
    poison_at = np.zeros((n_runs, J), bool)
    for i, e in enumerate(exps):
        if e.chaos is not None and e.chaos.spec.nan_fit_every:
            for r in range(n_runs):
                poison_at[r, i] = e.chaos._fires(
                    e.chaos.spec.nan_fit_every, e._run_idx + r + 1)

    # ---- learned state (stacked along the job axis)
    stack = lambda trees: jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *trees)
    params0 = stack([e.trainer.params for e in exps])
    opt0 = stack([e.trainer.opt for e in exps])
    fit_calls = np.array([e.trainer._fit_calls for e in exps], np.int32)
    base_key = np.stack(
        [np.asarray(jax.random.PRNGKey(e.seed ^ 0x5eed)) for e in exps])
    init_params = stack(
        [init_enel(jax.random.PRNGKey(e.seed)) for e in exps])
    lr = np.array([e.trainer.lr for e in exps], np.float32)

    snaps = [e.trainer.cache.snapshot() for e in exps]
    ring0 = {
        "buffers": {kk: np.stack([s["buffers"][kk] for s in snaps])
                    for kk in snaps[0]["buffers"]},
        "pos": np.array([s["pos"] for s in snaps], np.int32),
        "count": np.array([s["count"] for s in snaps], np.int32),
        "slot_ok": np.stack([s["slot_ok"] for s in snaps]),
    }
    interf0 = np.array(
        [backend.slot_state(i)["interf"] for i in range(J)], np.float32)

    # LAST: consume the backend RNG streams for the whole campaign
    blocks, kills = backend.campaign_run_blocks(n_runs)

    gather = lambda key_: jnp.asarray(
        np.stack([c[key_] for c in classes]))
    hgather = lambda key_: jnp.asarray(
        np.stack([hh[key_] for hh in hists]))
    dev = {
        "blocks": jnp.asarray(blocks), "kills": jnp.asarray(kills),
        "burst": const["burst"], "preempt": const["preempt"],
        "iscale2": const["iscale2"], "mem_tab": const["mem_tab"],
        "shuf_tab": const["shuf_tab"],
        "cand": jnp.asarray(cand), "cand_valid": jnp.asarray(cand_valid),
        "inject": jnp.asarray(inject), "target": jnp.asarray(target),
        "s0": jnp.asarray(s0), "n_comp": jnp.asarray(n_comp),
        "comp_valid": jnp.asarray(comp_valid),
        "decide_tab": jnp.asarray(decide_tab),
        "any_decide": jnp.asarray(any_decide),
        "n_stage_f": jnp.asarray(n_stage_f),
        "cursor_f": jnp.asarray(cursor_f),
        "cls": jnp.asarray(cls), "hcls": jnp.asarray(hcls),
        "sw_comp": jnp.arange(1, k_pad + 1, dtype=jnp.int32),
        "obs_ctx": gather("obs_ctx"), "p_ctx": gather("p_ctx"),
        "row_mask": gather("row_mask"), "row_summ": gather("row_summ"),
        "row_is_stage": gather("row_is_stage"),
        "row_is_p": gather("row_is_p"), "row_is_h": gather("row_is_h"),
        "row_stage_idx": gather("row_stage_idx"),
        "row_adj": gather("row_adj"),
        "sw_mask0": gather("sw_mask0"), "sw_summ": gather("sw_summ"),
        "sw_is_stage": gather("sw_is_stage"),
        "sw_is_p": gather("sw_is_p"), "sw_is_h": gather("sw_is_h"),
        "sw_stage_idx": gather("sw_stage_idx"), "sw_oh": gather("sw_oh"),
        "sw_adj": gather("sw_adj"),
        "sw_edge_dst": gather("sw_edge_dst"),
        "sw_edge_src": gather("sw_edge_src"),
        "sw_edge_val": gather("sw_edge_val"),
        "hob_ctx": hgather("hob_ctx"), "hob_met": hgather("hob_met"),
        "hob_val": hgather("hob_val"),
        "hob_start": hgather("hob_start"), "hob_end": hgather("hob_end"),
        "hsw_ctx": hgather("hsw_ctx"), "hsw_met": hgather("hsw_met"),
        "hsw_val": hgather("hsw_val"),
        "hsw_start": hgather("hsw_start"), "hsw_end": hgather("hsw_end"),
        "init_params": init_params, "base_key": jnp.asarray(base_key),
        "lr": jnp.asarray(lr),
        "dropout_p": jnp.float32(metric_dropout),
        "scratch_at": jnp.asarray(scratch_at),
        "poison_at": jnp.asarray(poison_at),
    }
    init = {
        "clock": np.zeros(J, np.float32), "interf": interf0,
        "s_prev": s0.copy(), "s_cur": s0.copy(),
        "p_met": np.zeros((J, N_METRICS), np.float32),
        "p_a": np.ones(J, np.float32), "p_z": np.ones(J, np.float32),
        "ring": ring0,
        "params": params0, "opt": opt0,
        "fit_calls": fit_calls,
        "fallbacks": np.zeros(J, np.int32),
        "nonfinite": np.zeros(J, np.int32),
    }
    if telemetry is None:
        from repro import obs as _obs
        telemetry = _obs.enabled()
    if telemetry:
        init["tel"] = {
            "last_dec_t": np.full(J, -1, np.int32),
            "run_fallbacks": np.zeros(J, np.int32),
            "run_nonfinite": np.zeros(J, np.int32),
            "run_fit_skip": np.zeros(J, np.int32),
            "gap_sum": np.zeros(J, np.int32),
            "gap_n": np.zeros(J, np.int32),
        }
    static = PlanStatic(
        c_max=c_max, s_max=s_max, lo=lo, tune_rows=pow2_bucket(c_max),
        scratch_steps=_round_steps(steps),
        tune_steps=_round_steps(fine_tune_steps),
        retrain_every=retrain_every,
        use_kernel=graph_prop_kernel_enabled(), levels=levels,
        telemetry=bool(telemetry))
    host = {
        "predicted": predicted, "targets": target.copy(),
        "n_comp": n_comp.copy(), "decide_tab": decide_tab.copy(),
        "comp_valid": comp_valid.copy(),
        "n_stage": n_stage_f.astype(np.int32),
        "s0": s0.astype(np.int32),
        "job_names": [e.job.name for e in exps],
        "run_idx0": [e._run_idx for e in exps],
        "n_runs": int(n_runs),
        "scratch_at": scratch_at.copy(),
    }
    return CampaignPlan(static, dev, init, host)
