"""Model-free fallback scaling policy (decision guardrail backstop).

When the learned model's sweep predictions are unusable — non-finite totals
from a poisoned fit, a dispatch that exhausted its retries, an open circuit
breaker, or a request shed under overload — the control plane must still
answer with SOME bounded scale-out (Daedalus-style graceful degradation:
the autoscaler keeps serving decisions while its model is unavailable).

:class:`FallbackPolicy` implements an Ernest-style clamp: salvage a
compliant pick from whatever finite predictions survive, otherwise step the
current allocation up by an urgency-scaled bounded amount.  Its contract —
property-tested in ``tests/test_fallback.py`` — is that the returned
scale-out is ALWAYS one of the real candidates (hence always inside
``[min_scaleout, max_scaleout]``), for arbitrary finite/non-finite
prediction vectors, elapsed times and targets.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union


def _finite(x) -> bool:
    try:
        return math.isfinite(float(x))
    except (TypeError, ValueError, OverflowError):
        return False


@dataclass
class FallbackPolicy:
    """Bounded heuristic scale-out picker for unusable predictions.

    ``max_step`` caps how many executors a single blind decision may add;
    ``press_lo``/``press_hi`` are elapsed/target urgency thresholds below
    which the policy holds, half-steps and full-steps respectively.
    """

    max_step: int = 4
    press_lo: float = 0.5
    press_hi: float = 0.85

    # ------------------------------------------------------------- decide
    def decide(self, candidates: Sequence[int],
               totals: Optional[Union[Dict[int, float], Sequence[float]]],
               current: int, elapsed: float, target: float
               ) -> Tuple[int, float]:
        """(scale-out, predicted_total) with predicted NaN when no finite
        prediction backed the pick.

        Salvage first: if any candidate kept a finite predicted total, run
        the normal smallest-compliant-else-least-violating pick over that
        finite subset.  Otherwise fall back to :meth:`clamp`.
        """
        finite = self._finite_totals(candidates, totals)
        if finite:
            if _finite(target):
                feasible = [s for s, t in finite.items() if t <= target]
                if feasible:
                    best = min(feasible)
                    return best, finite[best]
            best = min(finite, key=lambda s: (finite[s], s))
            return best, finite[best]
        return self.clamp(candidates, current, elapsed, target), float("nan")

    @staticmethod
    def _finite_totals(candidates, totals) -> Dict[int, float]:
        if totals is None:
            return {}
        if isinstance(totals, dict):
            pairs = [(s, totals.get(s)) for s in candidates]
        else:
            pairs = list(zip(candidates, totals))
        return {int(s): float(t) for s, t in pairs
                if t is not None and _finite(t)}

    # -------------------------------------------------------------- clamp
    def clamp(self, candidates: Sequence[int], current: int,
              elapsed: float, target: float) -> int:
        """Model-free bounded step: scale out by an urgency-proportional
        amount from the current allocation, clamped to the candidate range.

        Urgency is ``elapsed / target``: under ``press_lo`` hold the current
        scale-out, under ``press_hi`` add half of ``max_step``, above it add
        the full ``max_step`` (the run is about to blow its target and blind
        scale-out is the only lever left).  Non-finite elapsed/target means
        no urgency signal at all: hold the (clamped) current scale-out.
        """
        cands = sorted({int(s) for s in candidates})
        if not cands:
            raise ValueError("fallback needs at least one candidate")
        lo, hi = cands[0], cands[-1]
        cur = int(current) if _finite(current) else lo
        cur = min(max(cur, lo), hi)
        step = 0
        if _finite(elapsed) and _finite(target) and target > 0 \
                and elapsed >= 0:
            urgency = elapsed / target
            if urgency >= self.press_hi:
                step = self.max_step
            elif urgency >= self.press_lo:
                step = max(1, self.max_step // 2)
        want = min(max(cur + step, lo), hi)
        for s in cands:                    # smallest candidate >= want
            if s >= want:
                return s
        return hi


def fallback_pick(candidates, cand_valid, totals, current, elapsed, target,
                  max_step: int = 4, press_lo: float = 0.5,
                  press_hi: float = 0.85):
    """Pure-jnp mirror of :meth:`FallbackPolicy.decide` for in-scan use.

    Returns the picked INDEX into ``candidates`` (an ascending, duplicate-free
    f32 vector with a ``cand_valid`` mask) instead of the scale-out value —
    the caller gathers ``candidates[idx]``.  Same contract as the host policy
    (property-tested against it in ``tests/test_fused_campaign.py``): salvage
    the smallest compliant candidate among finite totals, else the least
    (total, scale-out) pair, else the urgency-scaled bounded clamp.  All ops
    are pure jnp so the whole guardrail runs INSIDE a scanned campaign step.
    """
    import jax.numpy as jnp

    candidates = candidates.astype(jnp.float32)
    inf = jnp.float32(jnp.inf)
    finite = cand_valid & jnp.isfinite(totals)
    # salvage: smallest compliant candidate (candidates ascending -> first
    # feasible index), else first argmin of the finite totals (stable argmin
    # = smallest scale-out on ties, matching min(key=(total, s)))
    feasible = finite & (totals <= target)
    idx_feas = jnp.argmax(feasible)
    idx_min = jnp.argmin(jnp.where(finite, totals, inf))
    use_feas = jnp.isfinite(target) & jnp.any(feasible)
    idx_salvage = jnp.where(use_feas, idx_feas, idx_min)
    # clamp: urgency-proportional bounded step from the current allocation
    lo = jnp.min(jnp.where(cand_valid, candidates, inf))
    hi = jnp.max(jnp.where(cand_valid, candidates, -inf))
    cur = jnp.where(jnp.isfinite(current), current, lo)
    cur = jnp.clip(cur, lo, hi)
    ok_u = (jnp.isfinite(elapsed) & jnp.isfinite(target) & (target > 0)
            & (elapsed >= 0))
    urgency = elapsed / target
    half = max(1, max_step // 2)
    step = jnp.where(ok_u & (urgency >= press_hi), jnp.float32(max_step),
                     jnp.where(ok_u & (urgency >= press_lo),
                               jnp.float32(half), jnp.float32(0.0)))
    want = jnp.clip(cur + step, lo, hi)
    ge = cand_valid & (candidates >= want)
    idx_hi = jnp.argmax(jnp.where(cand_valid, candidates, -inf))
    idx_clamp = jnp.where(jnp.any(ge), jnp.argmax(ge), idx_hi)
    return jnp.where(jnp.any(finite), idx_salvage, idx_clamp).astype(
        jnp.int32)
