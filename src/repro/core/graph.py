"""Attributed component DAGs, padded to fixed size for jit (paper §III-A/D).

A dataflow job execution is a sequence of component graphs G(1..n); each node
is a set of parallel tasks attributed with context embeddings, metrics,
start/end scale-out and the fraction of time spent in each.  Summary nodes
P(k) (current component) and H(k) (mean of the beta most scale-out-similar
historical summaries) are prepended as predecessors of the next component's
roots and participate only in metric propagation (flagged ``is_summary``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence

import numpy as np

MAX_NODES = 16          # padded node count per component graph
N_METRICS = 5           # CPU util, shuffle r/w, data I/O, GC frac, spill ratio
CTX_DIM = 24            # u ‖ v ‖ w, each an 8-dim AE embedding (paper: c in R^3N)
BETA = 3                # historical summaries averaged into H(k)


def scaleout_vec(s: np.ndarray) -> np.ndarray:
    """Ernest-style enrichment [1 - 1/s, log s, s] (paper §III-D)."""
    s = np.maximum(np.asarray(s, np.float32), 1e-6)
    return np.stack([1.0 - 1.0 / s, np.log(s), s], axis=-1)


@dataclass
class NodeAttrs:
    """One task-set node, host-side."""
    name: str
    context: np.ndarray                 # (CTX_DIM,)
    metrics: Optional[np.ndarray]       # (N_METRICS,) or None if unobserved
    start_scaleout: float
    end_scaleout: float
    time_fraction: float = 1.0          # r_i: fraction spent in end scale-out
    runtime: Optional[float] = None     # observed runtime (None = unobserved)
    overhead: Optional[float] = None    # observed rescale overhead
    is_summary: bool = False


@dataclass
class ComponentGraph:
    """Padded arrays for one component; built via :func:`build_graph`."""
    context: np.ndarray        # (MAX_NODES, CTX_DIM)
    metrics: np.ndarray        # (MAX_NODES, N_METRICS)
    metrics_valid: np.ndarray  # (MAX_NODES,) bool
    a_raw: np.ndarray          # (MAX_NODES,)
    z_raw: np.ndarray          # (MAX_NODES,)
    r: np.ndarray              # (MAX_NODES,)
    runtime: np.ndarray        # (MAX_NODES,)
    runtime_valid: np.ndarray  # (MAX_NODES,)
    overhead: np.ndarray       # (MAX_NODES,)
    overhead_valid: np.ndarray
    adj: np.ndarray            # (MAX_NODES, MAX_NODES) adj[i,j]: j -> i edge
    mask: np.ndarray           # (MAX_NODES,) real-node mask
    is_summary: np.ndarray     # (MAX_NODES,)
    names: List[str] = field(default_factory=list)
    component_id: int = 0

    @property
    def n_nodes(self) -> int:
        return int(self.mask.sum())


def build_graph(nodes: Sequence[NodeAttrs], edges: Sequence[tuple],
                component_id: int = 0, max_nodes: int = MAX_NODES
                ) -> ComponentGraph:
    n = len(nodes)
    if n > max_nodes:
        raise ValueError(f"{n} nodes > padded max {max_nodes}")
    g = ComponentGraph(
        context=np.zeros((max_nodes, CTX_DIM), np.float32),
        metrics=np.zeros((max_nodes, N_METRICS), np.float32),
        metrics_valid=np.zeros(max_nodes, bool),
        a_raw=np.ones(max_nodes, np.float32),
        z_raw=np.ones(max_nodes, np.float32),
        r=np.ones(max_nodes, np.float32),
        runtime=np.zeros(max_nodes, np.float32),
        runtime_valid=np.zeros(max_nodes, bool),
        overhead=np.zeros(max_nodes, np.float32),
        overhead_valid=np.zeros(max_nodes, bool),
        adj=np.zeros((max_nodes, max_nodes), bool),
        mask=np.zeros(max_nodes, bool),
        is_summary=np.zeros(max_nodes, bool),
        names=[a.name for a in nodes],
        component_id=component_id,
    )
    for i, a in enumerate(nodes):
        g.context[i] = a.context
        if a.metrics is not None:
            g.metrics[i] = a.metrics
            g.metrics_valid[i] = True
        g.a_raw[i] = max(a.start_scaleout, 1e-6)
        g.z_raw[i] = max(a.end_scaleout, 1e-6)
        g.r[i] = a.time_fraction
        if a.runtime is not None:
            g.runtime[i] = a.runtime
            g.runtime_valid[i] = True
        if a.overhead is not None:
            g.overhead[i] = a.overhead
            g.overhead_valid[i] = True
        g.mask[i] = True
        g.is_summary[i] = a.is_summary
    for (src, dst) in edges:
        g.adj[dst, src] = True
    return g


STACK_KEYS = ("context", "metrics", "metrics_valid", "a_raw", "z_raw", "r",
              "runtime", "runtime_valid", "overhead", "overhead_valid",
              "adj", "mask", "is_summary")


def stack_graphs(graphs: Sequence[ComponentGraph]) -> Dict[str, np.ndarray]:
    """Batch of padded graphs -> dict of stacked arrays for the jit model."""
    f = lambda attr: np.stack([getattr(g, attr) for g in graphs])
    return {k: f(k) for k in STACK_KEYS}


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (jit shape bucketing)."""
    b = 1
    while b < n:
        b *= 2
    return b


# ------------------------------------------------------ sweep bucket ladders
# Fixed shape ladders for the fleet decision service: every sweep is padded
# up to a rung of each ladder so a whole multi-job campaign compiles the
# decision jit once per visited (C, K, N, E, levels) rung combination —
# a handful of shapes total — instead of once per exact sweep shape.  The
# rungs are deliberately coarse: padded components/candidates are all-masked
# empty graphs that contribute exactly 0 (and are sliced off the result),
# and with the sparse-edge engine the padded compute is cheap.

CAND_LADDER = (6, 12, 18, 24, 36)        # candidate axis C
COMP_LADDER = (4, 8, 12, 16, 24, 32)     # remaining-component axis K
NODE_LADDER = (4, 8, 16)                 # node-slot axis N (compaction)
EDGE_LADDER = (2, 4, 6, 8, 16, 32)       # real-edge axis E (sparse engine)
LEVEL_LADDER = (2, 4, 6, 8)              # propagation depth (static arg)


def ladder_bucket(n: int, ladder: Sequence[int]) -> int:
    """Smallest ladder rung >= n; doubles past the last rung if needed."""
    for b in ladder:
        if b >= n:
            return b
    b = ladder[-1]
    while b < n:
        b *= 2
    return b


# --------------------------------------------------------------- sweep engine
# Batched candidate-sweep representation: across the candidate scale-out axis
# only (a_raw, z_raw, r, summary-node attributes) change, so a decision point
# is ONE candidate-invariant template per remaining component plus small
# per-candidate delta arrays, evaluated in a single jit (see core/scaling.py
# and model.sweep_per_component).

SWEEP_KEYS = ("context", "metrics", "metrics_valid", "a_raw", "z_raw", "r",
              "adj", "mask", "is_summary")


@dataclass
class SweepTemplate:
    """Candidate-invariant arrays for the K remaining components.

    ``base`` holds the stacked (K, MAX_NODES, ...) arrays of the template
    graphs (the subset of keys the forward pass reads, ``SWEEP_KEYS``).
    ``h_onehot[k, n]`` flags the node slot of component k's historical-summary
    H(k-1) node, whose attributes vary with the candidate scale-out;
    ``follows_a``/``follows_z`` flag nodes whose start/end scale-out track the
    builder's ``a``/``z`` arguments; ``r_eq``/``r_neq`` are the per-node time
    fractions when a == z vs. a != z.
    """
    base: Dict[str, np.ndarray]
    h_onehot: np.ndarray           # (K, MAX_NODES) float32
    a_follows_a: np.ndarray        # (K, MAX_NODES) bool: a_raw tracks `a`
    a_follows_z: np.ndarray        # (K, MAX_NODES) bool: a_raw tracks `z`
    z_follows_a: np.ndarray        # (K, MAX_NODES) bool
    z_follows_z: np.ndarray        # (K, MAX_NODES) bool
    r_eq: np.ndarray               # (K, MAX_NODES)
    r_neq: np.ndarray              # (K, MAX_NODES)
    comp_ids: List[int] = field(default_factory=list)
    levels: int = 8                # max DAG depth -> propagation rounds

    @property
    def n_components(self) -> int:
        return self.base["mask"].shape[0]


def propagation_depth(adj: np.ndarray, mask: np.ndarray) -> int:
    """Longest predecessor chain (in edges) of a padded DAG.

    Level-synchronous metric propagation reaches its fixed point after this
    many rounds, so the sweep can run exactly `depth` levels instead of the
    MAX_LEVELS worst case without changing a single bit of the result.
    """
    a = adj & mask[None, :] & mask[:, None]
    d = np.zeros(a.shape[0], np.int64)
    for _ in range(a.shape[0]):
        nd = np.where(a.any(axis=1), (a * (d[None, :] + 1)).max(axis=1), 0)
        if (nd == d).all():
            break
        d = nd
    return int(d.max())


def empty_graph(max_nodes: int = MAX_NODES) -> ComponentGraph:
    """Cached all-masked padding graph (bucketing filler)."""
    g = _EMPTY_GRAPHS.get(max_nodes)
    if g is None:
        g = build_graph([], [], max_nodes=max_nodes)
        _EMPTY_GRAPHS[max_nodes] = g
    return g


_EMPTY_GRAPHS: Dict[int, ComponentGraph] = {}


def historical_summaries_batch(candidates: Sequence[NodeAttrs],
                               targets: np.ndarray, beta: int = BETA
                               ) -> Dict[str, np.ndarray]:
    """Vectorized :func:`historical_summary` over a vector of target
    scale-outs.  Returns per-target H-node attribute arrays::

        context (C, CTX_DIM), metrics (C, N_METRICS), metrics_valid (C,),
        start (C,), end (C,)

    Matches the scalar path exactly: stable argsort on |end - target| mirrors
    the stable ``sorted`` ranking, means are taken over the beta chosen.
    """
    targets = np.asarray(targets, np.float32)
    ends = np.array([a.end_scaleout for a in candidates], np.float32)
    starts = np.array([a.start_scaleout for a in candidates], np.float32)
    ctxs = np.stack([a.context for a in candidates]).astype(np.float32)
    mets = np.stack([np.zeros(N_METRICS, np.float32) if a.metrics is None
                     else np.asarray(a.metrics, np.float32)
                     for a in candidates])
    mval = np.array([a.metrics is not None for a in candidates])
    d = np.abs(ends[None, :] - targets[:, None])           # (C, n_hist)
    idx = np.argsort(d, axis=1, kind="stable")[:, :beta]   # (C, chosen)
    chosen_valid = mval[idx]                               # (C, chosen)
    n_valid = chosen_valid.sum(axis=1)
    met_sum = (mets[idx] * chosen_valid[..., None]).sum(axis=1)
    metrics = met_sum / np.maximum(n_valid, 1)[:, None]
    return {"context": ctxs[idx].mean(axis=1),
            "metrics": metrics.astype(np.float32),
            "metrics_valid": n_valid > 0,
            "start": starts[idx].mean(axis=1),
            "end": ends[idx].mean(axis=1)}


def materialize_candidate(template: SweepTemplate,
                          deltas: Dict[str, np.ndarray],
                          c: int) -> Dict[str, np.ndarray]:
    """Apply candidate ``c``'s deltas host-side -> stacked (K, N, ...) dict.

    Reference path for testing/benchmarking the batched sweep: the result is
    exactly the graph batch the jit-side assembly produces for candidate c.
    """
    oh = template.h_onehot[..., None]                       # (K, N, 1)
    out = {k: v.copy() for k, v in template.base.items()}
    out["context"] = (out["context"] * (1.0 - oh) +
                      oh * deltas["h_context"][c][:, None, :])
    out["metrics"] = (out["metrics"] * (1.0 - oh) +
                      oh * deltas["h_metrics"][c][:, None, :])
    out["metrics_valid"] = deltas["metrics_valid"][c].astype(bool)
    out["a_raw"] = deltas["a_raw"][c]
    out["z_raw"] = deltas["z_raw"][c]
    out["r"] = deltas["r"][c]
    return out


# ------------------------------------------------------ sweep shape bucketing
def bucket_sweep(template: SweepTemplate, deltas: Dict[str, np.ndarray]
                 ) -> Tuple[SweepTemplate, Dict[str, np.ndarray],
                            Tuple[int, int]]:
    """Pad a (template, deltas) sweep to the fixed shape ladders.

    Returns the padded pair plus the REAL ``(n_candidates, n_components)``
    so callers can slice results back.  Padding semantics:

    * node axis N is COMPACTED to the smallest rung holding every real node
      slot (graphs fill slots from 0, so trailing slots are pure padding —
      dropping them is bit-exact: masked pairs contribute exact zeros);
    * component axis K is padded with all-masked empty graphs whose
      per-component readout is exactly 0;
    * candidate axis C is padded by repeating the last candidate's deltas
      (rows past the real count are sliced off / masked in the pick);
    * ``levels`` is rounded up to a rung — extra propagation rounds past the
      DAG depth are a fixed point, so the result is unchanged bit-for-bit.
    """
    c_real, k_real = deltas["a_raw"].shape[:2]
    n_now = template.base["mask"].shape[1]
    extent = 1
    if template.base["mask"].any():
        extent = int(np.flatnonzero(template.base["mask"].any(axis=0)).max()) + 1
    n_b = min(ladder_bucket(extent, NODE_LADDER), n_now)
    k_b = ladder_bucket(k_real, COMP_LADDER)
    c_b = ladder_bucket(c_real, CAND_LADDER)

    # which trailing structure each array key has around the node axis
    def fit_nodes(key: str, v: np.ndarray) -> np.ndarray:
        if key == "adj":
            return v[..., :n_b, :n_b]
        if key in ("context", "metrics"):            # (..., N, feature)
            return v[..., :n_b, :]
        if key in ("h_context", "h_metrics"):        # no node axis
            return v
        return v[..., :n_b]                          # (..., N)

    spec = _cache_spec(n_b)
    base = {}
    for key, v in template.base.items():
        v = fit_nodes(key, v)
        shape, dtype, fill = spec[key]
        pad = np.full((k_b - k_real,) + shape, fill, v.dtype)
        base[key] = np.concatenate([v, pad]) if k_b > k_real else v
    h_onehot = np.zeros((k_b, n_b), np.float32)
    h_onehot[:k_real] = template.h_onehot[:, :n_b]

    d_fill = {"a_raw": 1.0, "z_raw": 1.0, "r": 1.0, "metrics_valid": False,
              "h_context": 0.0, "h_metrics": 0.0}
    out = {}
    for key, v in deltas.items():
        v = fit_nodes(key, np.asarray(v))
        if k_b > k_real:
            pad = np.full((c_real, k_b - k_real) + v.shape[2:], d_fill[key],
                          v.dtype)
            v = np.concatenate([v, pad], axis=1)
        if c_b > c_real:
            v = np.concatenate([v, np.repeat(v[-1:], c_b - c_real, axis=0)])
        out[key] = v

    padded = replace(
        template, base=base, h_onehot=h_onehot,
        levels=min(ladder_bucket(max(template.levels, 1), LEVEL_LADDER),
                   LEVEL_LADDER[-1]))
    return padded, out, (c_real, k_real)


def sweep_edge_list(base: Dict[str, np.ndarray]
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-component (dst, src) edge lists for the sparse sweep engine.

    Returns ``(edge_dst, edge_src, edge_valid)`` of shape (K, E) with E the
    smallest EDGE_LADDER rung holding every component's real edge count.
    Padding edges point at slot 0 with ``edge_valid`` False — the engine
    masks them out of the softmax and both segment reductions.
    """
    adj = base["adj"] & base["mask"][:, None, :] & base["mask"][:, :, None]
    k = adj.shape[0]
    counts = adj.reshape(k, -1).sum(axis=1)
    e_b = ladder_bucket(max(int(counts.max()) if k else 1, 1), EDGE_LADDER)
    dst = np.zeros((k, e_b), np.int32)
    src = np.zeros((k, e_b), np.int32)
    val = np.zeros((k, e_b), bool)
    for ki in range(k):
        pairs = np.argwhere(adj[ki])               # (n_edges, 2): [dst, src]
        m = len(pairs)
        if m:
            dst[ki, :m] = pairs[:, 0]
            src[ki, :m] = pairs[:, 1]
            val[ki, :m] = True
    return dst, src, val


# ------------------------------------------------------------ training cache
# Device-resident ring buffer of stacked graphs: the runner appends each
# run's graphs ONCE, and every (re)fit trains straight on the resident
# (capacity, max_nodes, ...) buffers — one jit shape for the whole campaign
# instead of a host restack + transfer + shape-bucketed recompile per call.

def _cache_spec(max_nodes: int) -> Dict[str, tuple]:
    """(shape, dtype, fill) per stacked key; fills mirror build_graph's
    padding so an unfilled slot is exactly an ``empty_graph()``."""
    n = max_nodes
    return {
        "context": ((n, CTX_DIM), np.float32, 0.0),
        "metrics": ((n, N_METRICS), np.float32, 0.0),
        "metrics_valid": ((n,), bool, False),
        "a_raw": ((n,), np.float32, 1.0),
        "z_raw": ((n,), np.float32, 1.0),
        "r": ((n,), np.float32, 1.0),
        "runtime": ((n,), np.float32, 0.0),
        "runtime_valid": ((n,), bool, False),
        "overhead": ((n,), np.float32, 0.0),
        "overhead_valid": ((n,), bool, False),
        "adj": ((n, n), bool, False),
        "mask": ((n,), bool, False),
        "is_summary": ((n,), bool, False),
    }


def node_extent(g: ComponentGraph) -> int:
    """1 + index of the last real node slot (graphs fill slots from 0)."""
    idx = np.flatnonzero(g.mask)
    return int(idx.max()) + 1 if idx.size else 1


def _fit_nodes(v: np.ndarray, key: str, n: int) -> np.ndarray:
    """Slice or pad one graph attribute to ``n`` node slots."""
    spec = _cache_spec(n)[key]
    if key == "adj":
        out = np.full(spec[0], spec[2], spec[1])
        m = min(v.shape[0], n)
        out[:m, :m] = v[:m, :m]
        return out
    if v.shape[0] == n:
        return v.astype(spec[1], copy=False)
    out = np.full(spec[0], spec[2], spec[1])
    m = min(v.shape[0], n)
    out[:m] = v[:m]
    return out


def compact_rows(graphs: Sequence[ComponentGraph],
                 max_nodes: int) -> Dict[str, np.ndarray]:
    """Stack ONLY the given graphs, sliced/padded to ``max_nodes`` slots.

    Runner graphs are padded to MAX_NODES but hold far fewer real nodes
    (longest job: 5 stages + 2 summary preds); training on compact 8-slot
    rows quarters the dense N x N pair work with bit-identical losses (the
    dropped slots are fully masked).
    """
    return {k: np.stack([_fit_nodes(getattr(g, k), k, max_nodes)
                         for g in graphs]) for k in STACK_KEYS}


def ring_append(buffers, rows, idx):
    """Pure ring-buffer scatter: a NEW buffer pytree with ``rows`` written at
    ``idx`` (functional ``.at[].set``, no host state).  Shared by the jitted
    :func:`append_stacked` helper AND the fused campaign kernel, where the
    cache append must be a pure carry update inside ``lax.scan``."""
    import jax
    return jax.tree_util.tree_map(
        lambda b, v: b.at[idx].set(v.astype(b.dtype)), buffers, rows)


def _append_stacked_impl(buffers, rows, idx):
    return ring_append(buffers, rows, idx)


def _gather_rows_impl(buffers, idx):
    import jax
    return jax.tree_util.tree_map(lambda b: b[idx], buffers)


_JIT_HELPERS: Dict[str, object] = {}


def _jit_helper(name: str, fn):
    """jax.jit on first use — keeps this module importable without jax."""
    f = _JIT_HELPERS.get(name)
    if f is None:
        import jax
        f = jax.jit(fn)
        _JIT_HELPERS[name] = f
    return f


def append_stacked(buffers: Dict, rows: Dict, idx) -> Dict:
    """Scatter freshly-stacked rows into the device ring buffers at ``idx``
    (jitted; one compile per rows-per-append shape)."""
    return _jit_helper("append", _append_stacked_impl)(buffers, rows, idx)


# float keys scanned by the cache's non-finite quarantine (bool keys cannot
# be non-finite; adj is bool too)
_FINITE_KEYS = ("context", "metrics", "a_raw", "z_raw", "r", "runtime",
                "overhead")


class TrainingCache:
    """Device-resident ring buffer of stacked component graphs.

    ``extend`` appends incrementally (newest overwrite oldest once full);
    ``full_batch``/``latest_batch`` hand back resident device arrays plus a
    per-slot 0/1 weight vector for the loss — unfilled or padding slots are
    all-masked empty graphs with weight 0, so ring contents are equivalent
    to a one-shot :func:`stack_graphs` of the same graphs.

    Quarantine guardrail: rows carrying non-finite values are REPLACED by
    empty-graph rows and excluded from the loss weights (``slot_ok``).
    Zero-weighting alone would not be enough — ``NaN * 0 == NaN``, so one
    poisoned row inside the weighted loss reduction would still sink every
    fit.  ``extend`` quarantines on the way in;
    :meth:`quarantine_nonfinite` re-scans resident rows (self-healing after
    in-place corruption, e.g. chaos injection).
    """

    def __init__(self, capacity: int, max_nodes: int = 8):
        import jax.numpy as jnp
        self.capacity = int(capacity)
        self.max_nodes = int(max_nodes)
        self.buffers = {
            k: jnp.full((self.capacity,) + shape, fill, dtype)
            for k, (shape, dtype, fill) in _cache_spec(self.max_nodes).items()}
        self.pos = 0          # next write slot
        self.count = 0        # filled slots
        self.latest = np.zeros(0, np.int64)   # slots of the last extend()
        self.slot_ok = np.ones(self.capacity, bool)  # quarantine mask
        self.quarantined = 0  # rows replaced by empty graphs (lifetime)

    def _grow(self, new_nodes: int) -> None:
        """Reallocate with more node slots, padding existing rows."""
        import jax.numpy as jnp
        old = self.buffers
        grown = {}
        for k, (shape, dtype, fill) in _cache_spec(new_nodes).items():
            b = jnp.full((self.capacity,) + shape, fill, dtype)
            ov = old[k]
            if k == "adj":
                b = b.at[:, :ov.shape[1], :ov.shape[2]].set(ov)
            else:
                b = b.at[:, :ov.shape[1]].set(ov)
            grown[k] = b
        self.buffers = grown
        self.max_nodes = new_nodes

    def extend(self, graphs: Sequence[ComponentGraph]) -> np.ndarray:
        """Append graphs (newest kept if more than ``capacity``); returns the
        ring slots written — also remembered as ``latest`` for fine-tuning."""
        import jax.numpy as jnp
        graphs = list(graphs)[-self.capacity:]
        if not graphs:
            return np.zeros(0, np.int64)
        need = max(node_extent(g) for g in graphs)
        if need > self.max_nodes:
            self._grow(pow2_bucket(need))
        rows = compact_rows(graphs, self.max_nodes)
        ok = self._rows_finite(rows)
        if not ok.all():                # quarantine poisoned rows on entry
            empty = compact_rows([empty_graph(self.max_nodes)],
                                 self.max_nodes)
            for k in rows:
                rows[k][~ok] = empty[k][0]
            self.quarantined += int((~ok).sum())
        idx = (self.pos + np.arange(len(graphs))) % self.capacity
        self.buffers = append_stacked(
            self.buffers, {k: jnp.asarray(v) for k, v in rows.items()},
            jnp.asarray(idx))
        self.pos = int((self.pos + len(graphs)) % self.capacity)
        self.count = min(self.capacity, self.count + len(graphs))
        self.latest = idx
        self.slot_ok[idx] = ok
        return idx

    @staticmethod
    def _rows_finite(rows: Dict[str, np.ndarray]) -> np.ndarray:
        """(B,) bool: every float value of each stacked row is finite."""
        ok = None
        for k in _FINITE_KEYS:
            v = np.asarray(rows[k])
            fin = np.isfinite(v).all(axis=tuple(range(1, v.ndim)))
            ok = fin if ok is None else (ok & fin)
        return ok

    def quarantine_nonfinite(self) -> int:
        """Re-scan resident rows for non-finite values (one host fetch),
        replace offenders with empty-graph rows and drop them from
        ``slot_ok``.  Returns how many rows were newly quarantined —
        the self-healing path after in-place buffer corruption."""
        host = {k: np.asarray(self.buffers[k]) for k in _FINITE_KEYS}
        bad = ~self._rows_finite(host) & self.slot_ok
        n = int(bad.sum())
        if n == 0:
            return 0
        import jax.numpy as jnp
        empty = compact_rows([empty_graph(self.max_nodes)], self.max_nodes)
        idx = np.flatnonzero(bad)
        self.buffers = append_stacked(
            self.buffers,
            {k: jnp.asarray(np.repeat(v, n, axis=0))
             for k, v in empty.items()},
            jnp.asarray(idx))
        self.slot_ok[idx] = False
        self.quarantined += n
        return n

    def full_batch(self):
        """(device batch over ALL slots, per-slot weights) for scratch fits;
        quarantined slots train with weight 0."""
        w = np.zeros(self.capacity, np.float32)
        w[:self.count] = 1.0
        w *= self.slot_ok
        return self.buffers, w

    def latest_batch(self):
        """(gathered device batch, weights) over the newest extend(), padded
        to a power-of-two row count so fine-tunes share one jit shape;
        quarantined slots train with weight 0."""
        import jax.numpy as jnp
        m = len(self.latest)
        b = pow2_bucket(max(m, 1))
        idx = np.zeros(b, np.int64)
        idx[:m] = self.latest
        w = np.zeros(b, np.float32)
        w[:m] = self.slot_ok[self.latest]
        return _jit_helper("gather", _gather_rows_impl)(
            self.buffers, jnp.asarray(idx)), w

    # --------------------------------------------------- checkpoint support
    def snapshot(self) -> Dict:
        """Picklable host copy of the full ring state."""
        return {"capacity": self.capacity, "max_nodes": self.max_nodes,
                "pos": self.pos, "count": self.count,
                "latest": self.latest.copy(),
                "slot_ok": self.slot_ok.copy(),
                "quarantined": self.quarantined,
                "buffers": {k: np.asarray(v)
                            for k, v in self.buffers.items()}}

    @classmethod
    def from_snapshot(cls, st: Dict) -> "TrainingCache":
        import jax.numpy as jnp
        cache = cls(st["capacity"], max_nodes=st["max_nodes"])
        cache.buffers = {k: jnp.asarray(v)
                         for k, v in st["buffers"].items()}
        cache.pos = int(st["pos"])
        cache.count = int(st["count"])
        cache.latest = np.asarray(st["latest"]).copy()
        cache.slot_ok = np.asarray(st["slot_ok"]).copy()
        cache.quarantined = int(st["quarantined"])
        return cache

    def stacked_host(self) -> Dict[str, np.ndarray]:
        """Host copy of the filled slots, oldest -> newest (tests/debug)."""
        if self.count < self.capacity:
            order = np.arange(self.count)
        else:
            order = (self.pos + np.arange(self.capacity)) % self.capacity
        return {k: np.asarray(v)[order] for k, v in self.buffers.items()}


def summary_node(nodes: Sequence[NodeAttrs], name: str,
                 is_historical: bool = False) -> NodeAttrs:
    """P(k): mean context/metrics + component start/end scale-out (§III-D)."""
    real = [a for a in nodes if not a.is_summary]
    ctx = np.mean([a.context for a in real], axis=0)
    mets = [a.metrics for a in real if a.metrics is not None]
    m = np.mean(mets, axis=0) if mets else None
    return NodeAttrs(
        name=name, context=ctx.astype(np.float32),
        metrics=None if m is None else m.astype(np.float32),
        start_scaleout=real[0].start_scaleout,
        end_scaleout=real[-1].end_scaleout,
        time_fraction=1.0, is_summary=True)


def historical_summary(candidates: List[NodeAttrs], target_scaleout: float,
                       beta: int = BETA, name: str = "H") -> Optional[NodeAttrs]:
    """H(k): average of the beta scale-out-nearest historical summaries."""
    if not candidates:
        return None
    ranked = sorted(candidates,
                    key=lambda a: abs(a.end_scaleout - target_scaleout))
    chosen = ranked[:beta]
    ctx = np.mean([a.context for a in chosen], axis=0).astype(np.float32)
    mets = [a.metrics for a in chosen if a.metrics is not None]
    m = np.mean(mets, axis=0).astype(np.float32) if mets else None
    return NodeAttrs(
        name=name, context=ctx, metrics=m,
        start_scaleout=float(np.mean([a.start_scaleout for a in chosen])),
        end_scaleout=float(np.mean([a.end_scaleout for a in chosen])),
        time_fraction=1.0, is_summary=True)
