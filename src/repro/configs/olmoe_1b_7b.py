"""OLMoE-1B-7B — 64-expert top-8 MoE LM [arXiv:2409.02060; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=0,                  # no dense FFN: every layer is MoE
    moe_d_ff=1024,
    n_experts=64,
    top_k=8,
    vocab_size=50304,
    raw_vocab_size=50304,
    qk_norm=True,            # OLMoE uses QK-Norm
    grad_accum=2,
    rope_theta=10_000.0,
)
