"""Snowflake Arctic (480B) — 128-expert top-2 MoE + dense residual
[hf:Snowflake/snowflake-arctic-base].

56 heads is not divisible by the 16-way model axis: attention activations are
head-replicated across 'model' (weights remain storage-sharded); see DESIGN.md §4.
Adam moments are kept in bf16 so the single-pod (2+6)B/param footprint fits HBM.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,               # dense residual FFN width
    moe_d_ff=4864,
    n_experts=128,
    top_k=2,
    dense_residual=True,     # dense FFN in parallel with the MoE
    vocab_size=32000,
    raw_vocab_size=32000,
    rope_theta=10_000.0,
    opt_dtype="bfloat16",    # memory note in DESIGN.md §6
    grad_accum=16,
    grad_accum_dtype="bfloat16",
)
