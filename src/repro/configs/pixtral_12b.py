"""Pixtral-12B — Mistral-Nemo backbone + Pixtral-ViT frontend (STUB)
[hf:mistralai/Pixtral-12B-2409].

Per the assignment the vision frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings (batch, n_patches, d_model) that are concatenated
in front of the text token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=131072,
    raw_vocab_size=131072,
    n_patches=1024,          # one 1024-patch image per sequence
    grad_accum=8,
    rope_theta=1_000_000.0,
)
