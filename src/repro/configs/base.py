"""Config dataclasses for architectures and input shapes.

Every assigned architecture gets one ``<arch>.py`` in this package exporting
``CONFIG`` (the exact published configuration) built from :class:`ModelConfig`.
Reduced smoke variants are derived mechanically via :func:`smoke_config` so the
same code path is exercised at laptop scale.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. All LM-family archs share this schema."""

    name: str
    family: str                      # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int                  # padded for sharding (multiple of 128)
    raw_vocab_size: int              # published value; ids >= raw are masked

    # --- attention flavour ------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # >0: width of local attention layers
    local_global_period: int = 0     # p: (p-1) local layers then 1 global
    attn_logit_softcap: float = 0.0  # gemma2-style tanh cap on attn logits
    final_logit_softcap: float = 0.0
    qk_norm: bool = False            # qwen3 / gemma3 RMSNorm on q,k
    qkv_bias: bool = False           # qwen2.5
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d_model)
    abs_positions: bool = False      # whisper: sinusoidal absolute positions

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    moe_period: int = 1              # MoE applied at layers i % period == offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- hybrid (jamba) -------------------------------------------------------
    moe_group: int = 1024            # routing-group tokens (dispatch-einsum
                                     # FLOPs scale linearly with this; §Perf)

    # --- hybrid (jamba) -------------------------------------------------------
    attn_period: int = 0             # 0: all-attention; else 1 attn per period
    attn_index: int = 0              # position of attn layer within the period
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- ssm (xlstm) ----------------------------------------------------------
    slstm_period: int = 0            # 0: none; else 1 sLSTM per period
    slstm_index: int = 0

    # --- encoder-decoder (whisper) ---------------------------------------------
    enc_layers: int = 0
    enc_frames: int = 1500           # stub frontend emits this many frame embeddings

    # --- vlm (pixtral) ----------------------------------------------------------
    n_patches: int = 0               # stub frontend emits this many patch embeddings

    # --- numerics / perf knobs ---------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    opt_dtype: str = "float32"       # Adam moment dtype (arctic: bfloat16)
    norm_eps: float = 1e-6
    remat: str = "full"              # none | full
    scan_layers: bool = True
    grad_accum: int = 1              # accumulation steps at dp=16 (launch clamps
                                     # to keep >=1 sample per replica)
    grad_accum_dtype: str = "float32"
    seq_parallel_residual: bool = False  # Megatron-SP: shard the residual
                                         # stream's seq dim over 'model' (§Perf)
    rope_upcast: bool = False        # f32 rope application (baseline variant)
    moe_combine_f32: bool = False    # f32 combine tensor (baseline variant)
    ssm_io_f32: bool = False         # f32 sLSTM/mLSTM input projections
                                     # (baseline variant; cell math stays f32)
    head_pad_to: int = 0             # pad n_heads up for clean TP (perf knob)
    use_pallas: bool = False         # kernels validated separately; jnp path lowers
    max_position: int = 1 << 20

    # ------------------------------------------------------------------ helpers
    @property
    def layer_period(self) -> int:
        """Static period of the layer pattern (for scan-over-groups)."""
        p = 1
        for cand in (self.local_global_period, self.attn_period,
                     self.slstm_period, self.moe_period):
            if cand and cand > p:
                p = cand
        return p

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.layer_period

    @property
    def tail_layers(self) -> int:
        return self.n_layers - self.n_groups * self.layer_period

    @property
    def q_hidden(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_hidden(self) -> int:
        return self.n_kv_heads * self.d_head

    def layer_kind(self, i: int) -> str:
        """Mixer kind at absolute layer index i: attn|attn_local|mamba|mlstm|slstm."""
        if self.family == "ssm":
            return "slstm" if (self.slstm_period and
                               i % self.slstm_period == self.slstm_index) else "mlstm"
        if self.attn_period:
            return ("attn" if i % self.attn_period == self.attn_index else "mamba")
        if self.local_global_period:
            return ("attn" if i % self.local_global_period ==
                    self.local_global_period - 1 else "attn_local")
        if self.sliding_window and not self.local_global_period:
            return "attn_local"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """FFN kind at layer i: dense | moe | moe+dense | none."""
        if self.family == "ssm":
            return "none"                      # xlstm blocks carry their own expansion
        if self.n_experts and i % self.moe_period == self.moe_offset:
            return "moe+dense" if self.dense_residual else "moe"
        return "dense"

    def has_attention(self) -> bool:
        return self.family != "ssm"

    def attn_layer_indices(self) -> Tuple[int, ...]:
        return tuple(i for i in range(self.n_layers)
                     if self.layer_kind(i) in ("attn", "attn_local"))


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape. ``kind`` picks which step function is lowered."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable, reason). long_500k only for sub-quadratic families."""
    if shape.name == "long_500k" and cfg.family not in ("hybrid", "ssm"):
        return False, "full-attention arch: 512k dense-KV decode skipped (DESIGN.md §5)"
    return True, ""


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: pattern-preserving."""
    period = cfg.layer_period
    n_layers = max(2 * period, 2)            # >=2 groups so scan path is real
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_head=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=256,
        raw_vocab_size=251,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_frames=12 if cfg.enc_layers else cfg.enc_frames,
        n_patches=8 if cfg.n_patches else 0,
        mamba_d_state=4,
        remat="none",
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.n_experts:
        updates.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=32)
    return replace(cfg, **updates)


def describe(cfg: ModelConfig) -> str:
    fields = dataclasses.asdict(cfg)
    return "\n".join(f"{k}: {v}" for k, v in fields.items())
