"""Gemma-3 27B — 5:1 local:global attention, 128k context, qk-norm
[hf:google/gemma-3-1b-pt family scaling]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,             # 10 full (5L+1G) periods + 2 tail local layers
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab_size=262144,
    raw_vocab_size=262144,
    sliding_window=1024,
    local_global_period=6,   # 5 local then 1 global
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=1_000_000.0,  # global layers; local layers use 10k (attention.py)
    grad_accum=4,
)
