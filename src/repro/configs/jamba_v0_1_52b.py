"""Jamba-v0.1 (52B) — Mamba+attention 1:7 interleave, 16-expert top-2 MoE
every other layer [arXiv:2403.19887; hf].

Layer pattern (period 8, scanned 4x): attention at in-period index 4, Mamba
elsewhere; MoE FFN at odd in-period indices, dense FFN at even ones.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    moe_d_ff=14336,
    n_experts=16,
    top_k=2,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_index=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    grad_accum=4,
    vocab_size=65536,
    raw_vocab_size=65536,
    rope_theta=0.0,          # jamba attention layers carry no positional encoding
)
