"""xLSTM-350M — sLSTM + mLSTM block stack [arXiv:2405.04517].

xLSTM[7:1]: one sLSTM block per period of 8, the rest mLSTM (matrix-memory,
chunkwise-parallel).  d_ff=0 per the assignment: blocks carry their own
projection expansion, there is no separate FFN sublayer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_head=256,
    d_ff=0,
    vocab_size=50304,
    raw_vocab_size=50304,
    slstm_period=8,
    slstm_index=2,
    rope_theta=0.0,
    # f32 input projections: the bf16 variant triggers per-step convert
    # windows in XLA's scan autodiff and LOSES (§Perf hillclimb, refuted)
    ssm_io_f32=True,
)
