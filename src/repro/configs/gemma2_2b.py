"""Gemma-2 2B — local/global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

8 query heads are not divisible by the 16-way model axis: attention is
head-replicated across 'model' (DESIGN.md §4); the FFN keeps full TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab_size=256000,
    raw_vocab_size=256000,
    sliding_window=4096,
    local_global_period=2,   # alternate local, global
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    embed_scale=True,
    grad_accum=2,
    rope_theta=10_000.0,
)
