"""Whisper-medium — encoder-decoder audio LM [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (batch, enc_frames, d_model).  Vocab 51865 is
padded to 51968 = 16*3248 for clean vocab sharding (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,             # decoder layers
    enc_layers=24,
    enc_frames=1500,         # 30 s of audio at 50 Hz after the conv stub
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51968,
    raw_vocab_size=51865,
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions, not RoPE
    abs_positions=True,
)
