"""Qwen2.5-14B — GQA with QKV bias [hf:Qwen/Qwen2.5 family].

40 query heads are not divisible by the 16-way model axis: the baseline
head-replicates attention; the §Perf hillclimb sets head_pad_to=48 to restore
full tensor parallelism (20% padded-head FLOPs vs 16x replicated FLOPs).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=13824,
    vocab_size=152064,
    raw_vocab_size=152064,
    qkv_bias=True,
    grad_accum=8,
    rope_theta=1_000_000.0,
)
