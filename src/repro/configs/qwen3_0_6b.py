"""Qwen3-0.6B — GQA + qk-norm [hf:Qwen/Qwen3 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=3072,
    vocab_size=151936,
    raw_vocab_size=151936,
    qk_norm=True,
    grad_accum=2,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
