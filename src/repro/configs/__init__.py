"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Arch ids use the assignment spelling (dashes/dots); module names are the
pythonified equivalents.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
    smoke_config,
)

_ARCH_MODULES: Dict[str, str] = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "arctic-480b": "arctic_480b",
    "whisper-medium": "whisper_medium",
    "gemma2-2b": "gemma2_2b",
    "gemma3-27b": "gemma3_27b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen2.5-14b": "qwen2_5_14b",
    "pixtral-12b": "pixtral_12b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "xlstm-350m": "xlstm_350m",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {list(SHAPES)}")
    return SHAPES[name]


def all_cells():
    """All 40 (arch, shape) cells with applicability flags."""
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            cells.append((arch, shape.name, ok, reason))
    return cells
