"""Public model API: build/init/apply for any assigned architecture."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm

init_model = tfm.init_model
decode_step = tfm.decode_step
init_cache = tfm.init_cache
pad_cache_to = tfm.pad_cache_to


def apply_model(params, cfg: ModelConfig, batch: Dict):
    """Train-mode forward: (logits, aux_loss)."""
    logits, aux, _ = tfm.forward(params, cfg, batch, mode="train")
    return logits, aux


def prefill(params, cfg: ModelConfig, batch: Dict,
            cache_len: Optional[int] = None):
    """Prefill forward: (logits, cache). Cache padded to ``cache_len``."""
    logits, _, cache = tfm.forward(params, cfg, batch, mode="prefill")
    if cache_len is not None:
        cache = tfm.pad_cache_to(cache, cfg, cache_len)
    return logits, cache


def param_count(cfg: ModelConfig) -> int:
    """Total parameter count via eval_shape (no allocation)."""
    import math
    shapes = jax.eval_shape(lambda k: init_model(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: only top_k experts active)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    n_moe_layers = sum(1 for i in range(cfg.n_layers)
                       if "moe" in cfg.ffn_kind(i))
    expert_params = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = n_moe_layers * expert_params * (cfg.n_experts - cfg.top_k)
    return total - inactive
