"""Mixture-of-Experts FFN: GShard-style dense dispatch, EP over 'model'.

Tokens are routed top-k with per-expert capacity; dispatch/combine tensors are
built per top-k slot (K materializations of (G,T,E,C) instead of one
(G,T,K,E,C)) and contracted with einsums so GSPMD shards experts over the
'model' axis without manual collectives.  The dispatch einsum FLOPs are real
and show up in cost_analysis — the §Perf hillclimb quantifies them.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import constrain



def init_moe(key, cfg: ModelConfig) -> Dict:
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale_out).astype(dt),
    }


def capacity(cfg: ModelConfig, group: int) -> int:
    c = int(math.ceil(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(4, ((c + 3) // 4) * 4)


def moe_ffn(p: Dict, cfg: ModelConfig, x: jax.Array) -> Dict[str, jax.Array]:
    """x: (B, S, d) -> {"out": (B, S, d), "aux_loss": scalar}."""
    b, s, d = x.shape
    t = min(s, cfg.moe_group)
    assert s % t == 0, (s, t)
    g = b * (s // t)
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, t)

    xg = x.reshape(g, t, d)
    logits = (xg.astype(jnp.float32) @ p["router"])          # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (G,T,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balancing auxiliary loss (Switch/GShard form)
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e), axis=(0, 1))
    aux = jnp.sum(me * ce) * e

    # dispatch/combine held in the model dtype: the f32 variants doubled the
    # memory-roofline term with no accuracy benefit (gates are normalized
    # and disjoint across slots; §Perf hillclimb). moe_combine_f32 restores
    # the baseline behaviour for before/after measurement.
    cdt = jnp.float32 if cfg.moe_combine_f32 else x.dtype
    dispatch = jnp.zeros((g, t, e, c), x.dtype)
    combine = jnp.zeros((g, t, e, c), cdt)
    counts = jnp.zeros((g, e), jnp.float32)
    for j in range(k):                                       # static top-k loop
        m_j = jax.nn.one_hot(gate_idx[..., j], e, dtype=jnp.float32)   # (G,T,E)
        pos_in_e = jnp.cumsum(m_j, axis=1) - m_j + counts[:, None, :]  # 0-based
        counts = counts + jnp.sum(m_j, axis=1)
        pos_j = jnp.sum(pos_in_e * m_j, axis=-1)             # (G,T)
        keep = (pos_j < c) & (jnp.sum(m_j, -1) > 0)
        slot = jax.nn.one_hot(pos_j, c, dtype=jnp.float32) * keep[..., None]
        contrib = jnp.einsum("gte,gtc->gtec", m_j, slot)
        dispatch = dispatch + contrib.astype(x.dtype)
        combine = combine + (contrib *
                             gate_vals[..., j, None, None]).astype(cdt)

    # expert compute, sharded e -> 'model'
    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xg)
    xe = constrain(xe, "ep", "dp", None, None)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["w_gate"]))
    h = h * jnp.einsum("egcd,edf->egcf", xe, p["w_up"])
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    ye = constrain(ye, "ep", "dp", None, None)
    out = jnp.einsum("egcd,gtec->gtd", ye, combine.astype(ye.dtype))
    return {"out": out.reshape(b, s, d).astype(x.dtype), "aux_loss": aux}
