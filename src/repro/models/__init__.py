from repro.models.model import (active_param_count, apply_model, decode_step,
                                init_cache, init_model, pad_cache_to,
                                param_count, prefill)

__all__ = ["active_param_count", "apply_model", "decode_step", "init_cache",
           "init_model", "pad_cache_to", "param_count", "prefill"]
