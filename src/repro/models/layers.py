"""Primitive layers: inits, norms, FFNs, embeddings, rotary embeddings.

All layers are pure functions over explicit param pytrees (nested dicts of
jnp arrays); stacked variants for ``lax.scan`` are produced by vmapping the
init over per-layer keys (see transformer.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import constrain


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm over the last (head) dim of (..., H, Dh) q/k tensors."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm_init(dim: int):
    return jnp.zeros((dim,), jnp.float32)  # stored as offset from 1.0


# --------------------------------------------------------------------------- FFN
def init_ffn(key, cfg: ModelConfig, d_ff: int):
    dt = _dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, d_ff, dt),
        "w_up": dense_init(k2, cfg.d_model, d_ff, dt),
        "w_down": dense_init(k3, d_ff, cfg.d_model, dt, scale=1.0 / math.sqrt(d_ff)),
    }


def ffn(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Gated (SwiGLU / GeGLU) FFN; hidden sharded over tp_ff."""
    act = jax.nn.gelu if cfg.embed_scale else jax.nn.silu   # gemma uses GeGLU
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    h = constrain(h, "dp", None, "tp_ff")
    return h @ params["w_down"]


# --------------------------------------------------------------------------- rotary
def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               upcast: bool = False) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32.

    sin/cos are computed in f32 from integer positions but APPLIED in x's
    dtype by default: f32 application (upcast=True, the pre-hillclimb
    baseline) materializes f32 (B,S,H,Dh) intermediates per layer that
    dominated the memory roofline term (§Perf)."""
    dt = jnp.float32 if upcast else x.dtype
    freqs = rope_frequencies(x.shape[-1], theta)                     # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs        # (B,S,Dh/2)
    cos = jnp.cos(angles)[:, :, None, :].astype(dt)
    sin = jnp.sin(angles)[:, :, None, :].astype(dt)
    x1, x2 = jnp.split(x.astype(dt), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(n_pos: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal absolute position table (n_pos, dim)."""
    log_timescale = math.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# --------------------------------------------------------------------------- embed
def embed_lookup(table: jax.Array, ids: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Vocab-sharded table lookup (GSPMD partitions the gather; DESIGN.md §4)."""
    x = jnp.take(table, ids, axis=0).astype(_dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed_logits(x: jax.Array, table: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B,S,d) @ (V,d)^T -> (B,S,V) logits, vocab-sharded, optional softcap."""
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    logits = constrain(logits, "dp", None, "vocab")
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
    return logits


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap
