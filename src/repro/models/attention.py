"""GQA attention covering every assigned flavour:

* grouped-query attention with broadcast-fused KV-head repeat,
* sliding-window (local) and alternating local/global layers (gemma2/3),
* attention-logit softcap (gemma2), qk-norm (olmoe/qwen3/gemma3),
* QKV bias (qwen2.5), cross-attention (whisper), rope / sinusoidal / none,
* head padding for clean 16-way TP when n_heads % 16 != 0 (perf knob),
* query-chunked exact attention for long sequences (mirrors the Pallas
  flash kernel's tiling so the lowered jnp path has realistic live buffers),
* decode against a (B, S, Kh, Dh) KV cache written at a traced position.

The Pallas kernels in ``repro.kernels`` implement the same contracts for TPU;
``ref.py`` oracles there are thin wrappers over these functions.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, head_rms_norm, softcap
from repro.models.sharding import constrain

NEG_INF = -2.0e38


def padded_heads(cfg: ModelConfig) -> int:
    return max(cfg.head_pad_to, cfg.n_heads)


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    hp = padded_heads(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, hp * cfg.d_head, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_hidden, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_hidden, dt),
        "wo": dense_init(ks[3], hp * cfg.d_head, cfg.d_model, dt,
                         scale=1.0 / math.sqrt(cfg.q_hidden)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hp * cfg.d_head,), dt)
        p["bk"] = jnp.zeros((cfg.kv_hidden,), dt)
        p["bv"] = jnp.zeros((cfg.kv_hidden,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.d_head,), jnp.float32)
        p["k_norm"] = jnp.zeros((cfg.d_head,), jnp.float32)
    return p


def _layer_theta(cfg: ModelConfig, kind: str) -> float:
    """gemma3 local layers keep the short-context 10k base frequency."""
    if kind == "attn_local" and cfg.rope_theta > 10_000.0:
        return 10_000.0
    return cfg.rope_theta


def _project_q(p, cfg: ModelConfig, x, positions, kind):
    hp = padded_heads(cfg)
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(*x.shape[:-1], hp, cfg.d_head)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = apply_rope(q, positions, _layer_theta(cfg, kind),
                       upcast=cfg.rope_upcast)
    return constrain(q, "dp", None, "tp_heads", None)


def _project_kv(p, cfg: ModelConfig, x, positions, kind):
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    k = k.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        k = apply_rope(k, positions, _layer_theta(cfg, kind),
                       upcast=cfg.rope_upcast)
    return k, v


def _expand_kv(x: jax.Array, hp: int) -> jax.Array:
    """(B,T,Kh,Dh) -> (B,T,Hp,Dh) via broadcast+reshape (fuses into the dot)."""
    b, t, kh, dh = x.shape
    g = hp // kh
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, t, kh, g, dh))
    return x.reshape(b, t, kh * g, dh)


def _mask_bias(kind: str, cfg: ModelConfig, q_pos: jax.Array, k_pos: jax.Array,
               causal: bool) -> jax.Array:
    """Additive mask (B, Sq, Sk) from (B, Sq)/(B, Sk) position vectors."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    allow = jnp.ones_like(d, dtype=bool)
    if causal:
        allow &= d >= 0
    if kind == "attn_local" and cfg.sliding_window:
        allow &= d < cfg.sliding_window
    return jnp.where(allow, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(cfg: ModelConfig, q, k, v, bias) -> jax.Array:
    """Exact attention on one query chunk. q:(B,Sq,H,Dh) k,v:(B,T,H,Dh)."""
    scale = 1.0 / math.sqrt(cfg.d_head)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if cfg.attn_logit_softcap:
        s = softcap(s, cfg.attn_logit_softcap)
    s = s + bias[:, None] if bias.ndim == 3 else s + bias
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", a, v)


_Q_CHUNK = 1024


def multi_head_attention(p, cfg: ModelConfig, x, positions, kind: str,
                         *, causal: bool = True,
                         kv_x: Optional[jax.Array] = None,
                         kv_positions: Optional[jax.Array] = None,
                         return_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross).

    With ``return_kv`` also returns the pre-expansion roped (k, v) —
    (B, T, Kh, Dh) — for prefill cache construction.
    """
    hp = padded_heads(cfg)
    q = _project_q(p, cfg, x, positions, kind)
    src = x if kv_x is None else kv_x
    src_pos = positions if kv_positions is None else kv_positions
    # kv_seq shards the key/value sequence over 'model' when heads are not
    # TP-shardable (arctic 56H, gemma2 8H, qwen2.5 40H): sequence-parallel
    # attention instead of head-replicated attention (DESIGN.md §4).
    k_raw, v_raw = _project_kv(p, cfg, src, src_pos, kind)
    k_raw = constrain(k_raw, "dp", "kv_seq", "tp_kv", None)
    v_raw = constrain(v_raw, "dp", "kv_seq", "tp_kv", None)
    k, v = _expand_kv(k_raw, hp), _expand_kv(v_raw, hp)

    sq = q.shape[1]
    if sq > _Q_CHUNK and sq % _Q_CHUNK == 0:
        nq = sq // _Q_CHUNK
        qc = q.reshape(q.shape[0], nq, _Q_CHUNK, hp, cfg.d_head)
        qpos = positions.reshape(positions.shape[0], nq, _Q_CHUNK)

        def chunk(_, inp):
            qi, pi = inp
            bias = _mask_bias(kind, cfg, pi, src_pos, causal)  # (B,Cq,T)
            bias = constrain(bias, "dp", None, "kv_seq")
            return None, _sdpa(cfg, qi, k, v, bias)

        _, out = jax.lax.scan(chunk, None,
                              (qc.transpose(1, 0, 2, 3, 4),
                               qpos.transpose(1, 0, 2)))
        out = out.transpose(1, 0, 2, 3, 4).reshape(q.shape[0], sq, hp, cfg.d_head)
    else:
        bias = _mask_bias(kind, cfg, positions, src_pos, causal)
        bias = constrain(bias, "dp", None, "kv_seq")
        out = _sdpa(cfg, q, k, v, bias)

    out = _finish(p, cfg, out)
    if return_kv:
        return out, (k_raw, v_raw)
    return out


def _finish(p, cfg: ModelConfig, out: jax.Array) -> jax.Array:
    hp = padded_heads(cfg)
    if hp > cfg.n_heads:                        # inert padded heads (DESIGN.md §4)
        head_mask = (jnp.arange(hp) < cfg.n_heads).astype(out.dtype)
        out = out * head_mask[None, None, :, None]
    out = constrain(out, "dp", None, "tp_heads", None)
    out = out.reshape(*out.shape[:-2], hp * cfg.d_head)
    return out @ p["wo"]


# ------------------------------------------------------------------- decode path
def init_kv_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    shape = (batch, seq, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p, cfg: ModelConfig, x, cache, pos, kind: str,
                     *, cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None):
    """One-token attention. x:(B,1,d); pos: scalar int32 (shared across batch).

    Self-attention writes (k,v) for the new token into the cache at ``pos`` and
    attends over positions <= pos (window-clipped for local layers).  With
    ``cross_kv`` the cache is ignored and full encoder K/V are attended.
    Returns (out, new_cache).
    """
    hp = padded_heads(cfg)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _project_q(p, cfg, x, positions, kind)

    if cross_kv is not None:
        ck, cv = cross_kv
        k, v = _expand_kv(ck, hp), _expand_kv(cv, hp)
        t = k.shape[1]
        bias = jnp.zeros((1, t), jnp.float32)
        out = _sdpa(cfg, q, k, v, bias)
        return _finish(p, cfg, out), cache

    k_new, v_new = _project_kv(p, cfg, x, positions, kind)
    ck = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                      (0, pos, 0, 0))
    ck = constrain(ck, "dp", "cache_seq", "tp_kv", None)
    cv = constrain(cv, "dp", "cache_seq", "tp_kv", None)

    t = ck.shape[1]
    kpos = jnp.arange(t, dtype=jnp.int32)
    allow = kpos <= pos
    if kind == "attn_local" and cfg.sliding_window:
        allow &= kpos > pos - cfg.sliding_window
    bias = jnp.where(allow, 0.0, NEG_INF).astype(jnp.float32)[None, :]  # (1,T)

    k, v = _expand_kv(ck, hp), _expand_kv(cv, hp)
    out = _sdpa(cfg, q, k.astype(q.dtype), v.astype(q.dtype), bias)
    return _finish(p, cfg, out), {"k": ck, "v": cv}
