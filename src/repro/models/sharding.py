"""Logical-axis activation sharding constraints.

Model code annotates activations with *logical* axis names; the launcher maps
them to mesh axes via :func:`set_rules`.  Outside a mesh context (unit tests,
single-device smoke runs) constraints are no-ops.

Logical axes used by the model code:
  dp         batch dim (data parallel; spans ('pod','data') on the multi-pod mesh)
  tp_heads   query-head dim           tp_kv     kv-head dim
  tp_ff      ffn hidden / d_inner / flattened head-hidden
  ep         expert dim               cache_seq KV-cache sequence dim
  vocab      vocabulary dim           seq       activation sequence dim (SP)
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def set_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]]) -> None:
    _state.mesh = mesh
    _state.rules = rules


def get_rules() -> Tuple[Optional[Mesh], Optional[Dict[str, Axis]]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


@contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]]):
    prev = get_rules()
    set_rules(mesh, rules)
    try:
        yield
    finally:
        set_rules(*prev)


def logical_spec(*names: Optional[str]) -> Optional[P]:
    mesh, rules = get_rules()
    if mesh is None or rules is None:
        return None
    return P(*[rules.get(n) if n else None for n in names])


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active logical rules (no-op if unset)."""
    mesh, rules = get_rules()
    if mesh is None or rules is None:
        return x
    assert x.ndim == len(names), (x.shape, names)
    spec = P(*[rules.get(n) if n else None for n in names])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
