"""Model assembly: scan-over-layer-groups LM covering all ten families.

A model is a stack of ``n_groups`` identical *groups* (scanned, so HLO size is
O(1) in depth) plus optional explicit *tail* layers (gemma3's 62 = 6x10 + 2).
Each in-group position has a static (mixer kind, ffn kind) pair derived from
the config's layer pattern.  Three modes share one code path:

  train    full-sequence forward, no cache
  prefill  full-sequence forward, emits a KV/state cache (padded to cache_len)
  decode   single token at traced position ``pos`` against the cache

Caches are pytrees mirroring the group structure with a leading group dim, so
`lax.scan` threads them as xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (embed_init, embed_lookup, ffn, init_ffn,
                                 norm_init, rms_norm, sinusoidal_positions,
                                 unembed_logits)
from repro.models.sharding import constrain


# ------------------------------------------------------------------------ init
def _init_layer(key, cfg: ModelConfig, kind: str, fkind: str,
                cross: bool = False) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": norm_init(cfg.d_model)}
    if kind in ("attn", "attn_local"):
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
    elif kind == "mamba":
        p["mamba"] = ssm_lib.init_mamba(ks[0], cfg)
    elif kind == "mlstm":
        p["mixer"] = ssm_lib.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["mixer"] = ssm_lib.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["ln_cross"] = norm_init(cfg.d_model)
        p["cross"] = attn_lib.init_attention(ks[1], cfg)
    if fkind != "none":
        p["ln2"] = norm_init(cfg.d_model)
        if fkind in ("dense", "moe+dense"):
            p["ffn"] = init_ffn(ks[2], cfg, cfg.d_ff)
        if fkind in ("moe", "moe+dense"):
            p["moe"] = moe_lib.init_moe(ks[3], cfg)
    return p


def _init_group(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    period = cfg.layer_period
    ks = jax.random.split(key, period)
    return {f"p{j}": _init_layer(ks[j], cfg, cfg.layer_kind(j),
                                 cfg.ffn_kind(j), cross)
            for j in range(period)}


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    """Encoder stack config for enc-dec archs: plain bidirectional attention."""
    import dataclasses
    return dataclasses.replace(cfg, local_global_period=0, sliding_window=0,
                               attn_period=0, slstm_period=0, n_experts=0,
                               rope_theta=0.0)


def init_model(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                            {"bfloat16": jnp.bfloat16,
                             "float32": jnp.float32}[cfg.param_dtype]),
        "final_norm": norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[1], cfg.vocab_size, cfg.d_model,
                                       jnp.float32).astype(params["embed"].dtype)
    cross = cfg.family == "audio"
    gkeys = jax.random.split(ks[2], cfg.n_groups)
    params["groups"] = jax.vmap(
        lambda k: _init_group(k, cfg, cross=cross))(gkeys)
    if cfg.tail_layers:
        tkeys = jax.random.split(ks[3], cfg.tail_layers)
        base = cfg.n_groups * cfg.layer_period
        params["tail"] = [
            _init_layer(tkeys[t], cfg, cfg.layer_kind(base + t),
                        cfg.ffn_kind(base + t), cross)
            for t in range(cfg.tail_layers)]
    if cfg.family == "audio":
        ecfg = _enc_cfg(cfg)
        ekeys = jax.random.split(ks[4], cfg.enc_layers)
        params["encoder"] = {
            "groups": jax.vmap(lambda k: _init_group(k, ecfg))(ekeys),
            "final_norm": norm_init(cfg.d_model),
        }
    return params


# --------------------------------------------------------------------- layers
def _layer_apply(lp: Dict, cfg: ModelConfig, kind: str, fkind: str,
                 x, mode: str, positions, cache: Optional[Dict],
                 pos, enc_out) -> Tuple[jax.Array, Dict, jax.Array]:
    """One block. Returns (x, new_cache_entry, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)

    if kind in ("attn", "attn_local"):
        if mode == "decode":
            y, upd = attn_lib.decode_attention(lp["attn"], cfg, h,
                                               {"k": cache["k"], "v": cache["v"]},
                                               pos, kind)
            new_cache.update(upd)
        elif mode == "prefill":
            y, (k, v) = attn_lib.multi_head_attention(
                lp["attn"], cfg, h, positions, kind, return_kv=True)
            new_cache["k"], new_cache["v"] = k, v
        else:
            y = attn_lib.multi_head_attention(lp["attn"], cfg, h, positions, kind)
    elif kind == "mamba":
        if mode == "decode":
            y, st = ssm_lib.mamba_step(lp["mamba"], cfg, h, cache)
            new_cache.update(st)
        elif mode == "prefill":
            y, st = ssm_lib.mamba_forward(lp["mamba"], cfg, h, return_state=True)
            new_cache.update(st)
        else:
            y = ssm_lib.mamba_forward(lp["mamba"], cfg, h)
    elif kind == "mlstm":
        if mode == "decode":
            y, st = ssm_lib.mlstm_step(lp["mixer"], cfg, h, cache)
            new_cache.update(st)
        elif mode == "prefill":
            y, st = ssm_lib.mlstm_forward(lp["mixer"], cfg, h, return_state=True)
            new_cache.update(st)
        else:
            y = ssm_lib.mlstm_forward(lp["mixer"], cfg, h)
    elif kind == "slstm":
        if mode == "decode":
            y, st = ssm_lib.slstm_step(lp["mixer"], cfg, h, cache)
            new_cache.update(st)
        elif mode == "prefill":
            y, st = ssm_lib.slstm_forward(lp["mixer"], cfg, h, return_state=True)
            new_cache.update(st)
        else:
            y = ssm_lib.slstm_forward(lp["mixer"], cfg, h)
    else:
        raise ValueError(kind)
    x = x + y

    if "cross" in lp:                                       # whisper decoder
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        if mode == "decode":
            y, _ = attn_lib.decode_attention(
                lp["cross"], cfg, h, {}, pos, "attn",
                cross_kv=(cache["ck"], cache["cv"]))
            new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
        else:
            enc_pos = jnp.broadcast_to(
                jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
                enc_out.shape[:2])
            out = attn_lib.multi_head_attention(
                lp["cross"], cfg, h, positions, "attn", causal=False,
                kv_x=enc_out, kv_positions=enc_pos,
                return_kv=(mode == "prefill"))
            if mode == "prefill":
                y, (ck, cv) = out
                new_cache["ck"], new_cache["cv"] = ck, cv
            else:
                y = out
        x = x + y

    if fkind != "none":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        y = jnp.zeros_like(x)
        if fkind in ("dense", "moe+dense"):
            y = y + ffn(lp["ffn"], cfg, h)
        if fkind in ("moe", "moe+dense"):
            r = moe_lib.moe_ffn(lp["moe"], cfg, h)
            y = y + r["out"]
            aux = aux + r["aux_loss"]
        x = x + y
    if cfg.seq_parallel_residual and mode == "train":
        # Megatron-SP: the residual stream (and thus the remat-scan carry)
        # lives sharded over 'model' on the sequence dim between blocks
        x = constrain(x, "dp", "sp", None)
    return x, new_cache, aux


def _group_apply(gp, cfg: ModelConfig, x, mode, positions, gcache, pos,
                 enc_out, layer_kinds):
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for j, (kind, fkind) in enumerate(layer_kinds):
        entry = gcache.get(f"p{j}") if gcache else None
        x, nc, a = _layer_apply(gp[f"p{j}"], cfg, kind, fkind, x, mode,
                                positions, entry, pos, enc_out)
        new_cache[f"p{j}"] = nc
        aux = aux + a
    return x, new_cache, aux


def _scan_groups(groups, cfg: ModelConfig, x, mode, positions, cache_groups,
                 pos, enc_out, layer_kinds):
    def body(carry, inp):
        xc, aux = carry
        gp, gc = inp
        xc, nc, a = _group_apply(gp, cfg, xc, mode, positions, gc, pos,
                                 enc_out, layer_kinds)
        return (xc, aux + a), nc

    if cfg.remat == "full" and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (groups, cache_groups if cache_groups is not None else {})
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_cache, aux


def _decoder_kinds(cfg: ModelConfig):
    return [(cfg.layer_kind(j), cfg.ffn_kind(j)) for j in range(cfg.layer_period)]


# -------------------------------------------------------------------- encoder
def encode_audio(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, F, d)."""
    ecfg = _enc_cfg(cfg)
    x = frames + sinusoidal_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32)[None], frames.shape[:2])

    def body(carry, gp):
        xc, _ = carry
        h = rms_norm(xc, gp["p0"]["ln1"], cfg.norm_eps)
        y = attn_lib.multi_head_attention(gp["p0"]["attn"], ecfg, h, positions,
                                          "attn", causal=False)
        xc = xc + y
        h = rms_norm(xc, gp["p0"]["ln2"], cfg.norm_eps)
        xc = xc + ffn(gp["p0"]["ffn"], cfg, h)
        return (xc, jnp.zeros((), jnp.float32)), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["encoder"]["groups"])
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


# ------------------------------------------------------------------- forward
def _embed_input(params, cfg: ModelConfig, batch: Dict) -> Tuple[jax.Array, jax.Array]:
    """Token (+patch) embedding and positions. Returns (x, positions)."""
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens, cfg)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.abs_positions:
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    return x, positions


def forward(params, cfg: ModelConfig, batch: Dict,
            mode: str = "train", cache: Optional[Dict] = None):
    """Full-sequence forward. Returns (logits, aux, new_cache_or_None)."""
    enc_out = None
    if cfg.family == "audio":
        enc_out = encode_audio(params, cfg, batch["frames"])
    x, positions = _embed_input(params, cfg, batch)
    x = constrain(x, "dp", None, None)
    kinds = _decoder_kinds(cfg)
    x, gcache, aux = _scan_groups(params["groups"], cfg, x, mode, positions,
                                  None, None, enc_out, kinds)
    tail_cache = []
    base = cfg.n_groups * cfg.layer_period
    for t in range(cfg.tail_layers):
        x, nc, a = _layer_apply(params["tail"][t], cfg,
                                cfg.layer_kind(base + t), cfg.ffn_kind(base + t),
                                x, mode, positions, None, None, enc_out)
        tail_cache.append(nc)
        aux = aux + a
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed_logits(x, table, cfg)
    new_cache = None
    if mode == "prefill":
        new_cache = {"groups": gcache, "tail": tail_cache}
    return logits, aux, new_cache


def pad_cache_to(cache: Dict, cfg: ModelConfig, cache_len: int) -> Dict:
    """Grow prefill KV entries (B,P,Kh,Dh) to (B,cache_len,Kh,Dh)."""
    def _grow_entry(entry):
        out = dict(entry)
        for key in ("k", "v"):
            if key in entry:
                arr = entry[key]
                pad = cache_len - arr.shape[-3]
                if pad > 0:
                    cfgpad = [(0, 0)] * arr.ndim
                    cfgpad[-3] = (0, pad)
                    out[key] = jnp.pad(arr, cfgpad)
        return out

    groups = {k: _grow_entry(v) for k, v in cache["groups"].items()}
    tail = [_grow_entry(e) for e in cache["tail"]]
    return {"groups": groups, "tail": tail}


# -------------------------------------------------------------------- decode
def decode_step(params, cfg: ModelConfig, cache: Dict, token: jax.Array,
                pos: jax.Array):
    """token: (B,1) int32; pos: scalar int32. Returns (logits (B,1,V), cache)."""
    x = embed_lookup(params["embed"], token, cfg)
    if cfg.abs_positions:
        table = sinusoidal_positions(cache_seq_len(cfg, cache), cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(table, pos, 1, 0)[None].astype(x.dtype)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    kinds = _decoder_kinds(cfg)
    x, gcache, _ = _scan_groups(params["groups"], cfg, x, "decode", positions,
                                cache["groups"], pos, None, kinds)
    tail_cache = []
    base = cfg.n_groups * cfg.layer_period
    for t in range(cfg.tail_layers):
        x, nc, _ = _layer_apply(params["tail"][t], cfg,
                                cfg.layer_kind(base + t), cfg.ffn_kind(base + t),
                                x, "decode", positions, cache["tail"][t], pos, None)
        tail_cache.append(nc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed_logits(x, table, cfg)
    return logits, {"groups": gcache, "tail": tail_cache}


def cache_seq_len(cfg: ModelConfig, cache: Dict) -> int:
    for j in range(cfg.layer_period):
        entry = cache["groups"][f"p{j}"]
        if "k" in entry:
            return entry["k"].shape[-3]
    for entry in cache["tail"]:
        if "k" in entry:
            return entry["k"].shape[-3]
    return 0


# ---------------------------------------------------------------- cache init
def _entry_struct(cfg: ModelConfig, kind: str, batch: int, seq: int,
                  cross: bool, dtype) -> Dict:
    di, _ = ssm_lib.mamba_dims(cfg)
    if kind in ("attn", "attn_local"):
        e = {"k": jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.d_head), dtype),
             "v": jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.d_head), dtype)}
        if cross:
            e["ck"] = jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads,
                                 cfg.d_head), dtype)
            e["cv"] = jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads,
                                 cfg.d_head), dtype)
        return e
    if kind == "mamba":
        return {"h": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
                "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype)}
    if kind == "mlstm":
        return ssm_lib.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return ssm_lib.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> Dict:
    """Zero cache pytree matching decode_step's expectations."""
    cross = cfg.family == "audio"
    period = cfg.layer_period

    def stack(e):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_groups,) + a.shape), e)

    groups = {f"p{j}": stack(_entry_struct(cfg, cfg.layer_kind(j), batch, seq,
                                           cross, dtype))
              for j in range(period)}
    base = cfg.n_groups * period
    tail = [_entry_struct(cfg, cfg.layer_kind(base + t), batch, seq, cross, dtype)
            for t in range(cfg.tail_layers)]
    return {"groups": groups, "tail": tail}
