"""State-space / recurrent mixers: Mamba (jamba), mLSTM + sLSTM (xlstm).

Each mixer exposes a full-sequence form (train/prefill; parallel where the
math allows) and a single-step decode form carrying an explicit state pytree.
The mLSTM chunkwise form mirrors the ``mlstm_chunk`` Pallas kernel; the
fully-recurrent reference lives in ``repro.kernels.mlstm_chunk.ref``.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.sharding import constrain


def _pdt(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


# ======================================================================= Mamba
def mamba_dims(cfg: ModelConfig) -> Tuple[int, int]:
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return d_inner, dt_rank


def init_mamba(key, cfg: ModelConfig) -> Dict:
    dt = _pdt(cfg)
    di, r = mamba_dims(cfg)
    n = cfg.mamba_d_state
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, di), jnp.float32)
                   / math.sqrt(cfg.mamba_d_conv)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dt),
        "dt_proj": dense_init(ks[3], r, di, dt),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1) init
            jax.random.uniform(ks[4], (di,), jnp.float32, 1e-3, 1e-1))),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, cfg.d_model, dt),
    }


def _mamba_conv_full(p, x1):
    """Causal depthwise conv along S. x1: (B,S,dI)."""
    dconv = p["conv_w"].shape[0]
    out = jnp.zeros_like(x1)
    for i in range(dconv):  # static small loop (d_conv=4)
        shift = dconv - 1 - i
        xs = jnp.pad(x1, ((0, 0), (shift, 0), (0, 0)))[:, :x1.shape[1]]
        out = out + xs * p["conv_w"][i].astype(x1.dtype)
    return out + p["conv_b"].astype(x1.dtype)


def _mamba_core(p, cfg: ModelConfig, x1):
    """Shared per-token SSM inputs. x1: (B,S,dI) post-conv post-silu."""
    di, r = mamba_dims(cfg)
    n = cfg.mamba_d_state
    dbc = x1 @ p["x_proj"]
    dt_raw, bc, cc = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus((dt_raw @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])                       # (B,S,dI)
    a = -jnp.exp(p["A_log"])                                   # (dI,N)
    decay = jnp.exp(dt[..., None] * a)                         # (B,S,dI,N)
    drive = (dt * x1.astype(jnp.float32))[..., None] * \
        bc.astype(jnp.float32)[:, :, None, :]                  # (B,S,dI,N)
    return decay, drive, cc.astype(jnp.float32)


def mamba_forward(p: Dict, cfg: ModelConfig, x: jax.Array,
                  return_state: bool = False):
    """x: (B,S,d) -> (B,S,d) [, final state]. Parallel associative scan."""
    di, _ = mamba_dims(cfg)
    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = constrain(x1, "dp", None, "tp_ff")
    x1 = jax.nn.silu(_mamba_conv_full(p, x1))
    decay, drive, cc = _mamba_core(p, cfg, x1)

    def comb(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])

    _, h = jax.lax.associative_scan(comb, (decay, drive), axis=1)  # (B,S,dI,N)
    y = jnp.einsum("bsdn,bsn->bsd", h, cc)
    y = (y + p["D"] * x1.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, "dp", None, "tp_ff")
    out = y @ p["out_proj"]
    if not return_state:
        return out
    dconv = p["conv_w"].shape[0]
    # conv tail of the *pre-activation* conv inputs == last (dconv-1) x1-pre
    xz_tail = (x @ p["in_proj"])[:, -(dconv - 1):, :di]
    state = {"h": h[:, -1], "conv": xz_tail}
    return out, state


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    di, _ = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
    }


def mamba_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict):
    """Single-token decode. x: (B,1,d) -> (B,1,d), new state."""
    di, _ = mamba_dims(cfg)
    xz = x @ p["in_proj"]
    x1_pre, z = jnp.split(xz[:, 0], 2, axis=-1)                # (B,dI)
    window = jnp.concatenate([state["conv"].astype(x1_pre.dtype),
                              x1_pre[:, None]], axis=1)        # (B,dconv,dI)
    x1 = jnp.einsum("bcd,cd->bd", window, p["conv_w"].astype(x1_pre.dtype))
    x1 = jax.nn.silu(x1 + p["conv_b"].astype(x1.dtype))[:, None]   # (B,1,dI)
    decay, drive, cc = _mamba_core(p, cfg, x1)
    h = decay[:, 0] * state["h"] + drive[:, 0]                 # (B,dI,N)
    y = jnp.einsum("bdn,bn->bd", h, cc[:, 0])
    y = (y + p["D"] * x1[:, 0].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": h, "conv": window[:, 1:]}


# ======================================================================= mLSTM
def init_mlstm(key, cfg: ModelConfig) -> Dict:
    dt = _pdt(cfg)
    d, h = cfg.d_model, cfg.n_heads
    hid = h * cfg.d_head
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d, hid, dt),
        "wk": dense_init(ks[1], d, hid, dt),
        "wv": dense_init(ks[2], d, hid, dt),
        "w_gate": dense_init(ks[3], d, d, dt),
        "w_i": dense_init(ks[4], d, h, jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": dense_init(ks[5], d, h, jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # open forget gates at init
        "w_out": dense_init(ks[6], hid, d, dt),
    }


def _mlstm_qkvif(p, cfg, u):
    b, s, _ = u.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = (u @ p["wq"]).reshape(b, s, h, dh)
    k = (u @ p["wk"]).reshape(b, s, h, dh) / math.sqrt(dh)
    v = (u @ p["wv"]).reshape(b, s, h, dh)
    # gate projections in the model dtype (unless the f32 baseline variant);
    # the gate values themselves are f32 for the stabilized recurrence
    gdt = jnp.float32 if cfg.ssm_io_f32 else u.dtype
    i = (u.astype(gdt) @ p["w_i"].astype(gdt)).astype(jnp.float32) + p["b_i"]
    f = (u.astype(gdt) @ p["w_f"].astype(gdt)).astype(jnp.float32) + p["b_f"]
    lf = jax.nn.log_sigmoid(f)
    return q, k, v, i, lf


_CHUNK = 256


def mlstm_chunk_scan(q, k, v, i, lf, state=None):
    """Chunkwise-parallel stabilized mLSTM scan.

    q,k,v: (B,S,H,Dh); i,lf: (B,S,H).  Returns (h_out (B,S,H,Dh), state).
    State: C (B,H,Dh,Dh), n (B,H,Dh), m (B,H).
    """
    b, s, h, dh = q.shape
    L = min(_CHUNK, s)
    assert s % L == 0
    nc = s // L
    f32 = jnp.float32
    if state is None:
        state = {"C": jnp.zeros((b, h, dh, dh), f32),
                 "n": jnp.zeros((b, h, dh), f32),
                 "m": jnp.full((b, h), -1e30, f32)}

    def chunk(carry, inp):
        C, n, m = carry
        qc, kc, vc, ic, lfc = inp                              # (B,L,...)
        F = jnp.cumsum(lfc, axis=1)                            # inclusive (B,L,H)
        # intra log-weights D[t,s] = F_t - F_s + i_s  (s<=t)
        Dm = F[:, :, None, :] - F[:, None, :, :] + ic[:, None, :, :]
        tidx = jnp.arange(L)
        causal = (tidx[:, None] >= tidx[None, :])[None, :, :, None]
        Dm = jnp.where(causal, Dm, -jnp.inf)
        m_intra = jnp.max(Dm, axis=2)                          # (B,L,H)
        m_inter = F + m[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)                    # (B,L,H)
        w_intra = jnp.exp(Dm - m_t[:, :, None, :])             # (B,L,L,H)
        w_inter = jnp.exp(m_inter - m_t)                       # (B,L,H)

        scores = jnp.einsum("blhd,bshd->blsh", qc.astype(f32), kc.astype(f32))
        num = jnp.einsum("blsh,bshd->blhd", w_intra * scores, vc.astype(f32)) \
            + w_inter[..., None] * jnp.einsum("blhd,bhde->blhe", qc.astype(f32), C)
        den = jnp.einsum("blsh->blh", w_intra * scores) \
            + w_inter * jnp.einsum("blhd,bhd->blh", qc.astype(f32), n)
        h_out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # end-of-chunk state
        Ftot = F[:, -1]                                        # (B,H)
        m_end = m_t[:, -1]
        g_old = jnp.exp(Ftot + m - m_end)                      # (B,H)
        w_end = jnp.exp(Ftot[:, None] - F + ic - m_end[:, None])   # (B,L,H)
        C_new = g_old[:, :, None, None] * C + \
            jnp.einsum("blh,blhd,blhe->bhde", w_end, kc.astype(f32), vc.astype(f32))
        n_new = g_old[:, :, None] * n + \
            jnp.einsum("blh,blhd->bhd", w_end, kc.astype(f32))
        return (C_new, n_new, m_end), h_out

    resh = lambda x: x.reshape(b, nc, L, *x.shape[2:]).transpose(
        1, 0, 2, *range(3, x.ndim + 1))
    (C, n, m), hs = jax.lax.scan(
        chunk, (state["C"], state["n"], state["m"]),
        (resh(q), resh(k), resh(v), resh(i), resh(lf)))
    h_out = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return h_out.astype(q.dtype), {"C": C, "n": n, "m": m}


def mlstm_forward(p: Dict, cfg: ModelConfig, u: jax.Array,
                  state=None, return_state: bool = False):
    """Mixer body (u is already normed). u: (B,S,d)."""
    q, k, v, i, lf = _mlstm_qkvif(p, cfg, u)
    v = constrain(v, "dp", None, None, "tp_ff")
    h_out, new_state = mlstm_chunk_scan(q, k, v, i, lf, state)
    gate = jax.nn.silu(u @ p["w_gate"])
    out = h_out.reshape(*u.shape[:2], -1) * gate
    out = out @ p["w_out"]
    if return_state:
        return out, new_state
    return out


def mlstm_init_state(cfg: ModelConfig, batch: int) -> Dict:
    h, dh = cfg.n_heads, cfg.d_head
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_step(p: Dict, cfg: ModelConfig, u: jax.Array, state: Dict):
    """Single-token recurrence. u: (B,1,d)."""
    q, k, v, i, lf = _mlstm_qkvif(p, cfg, u)
    f32 = jnp.float32
    q0, k0, v0 = (t[:, 0].astype(f32) for t in (q, k, v))
    i0, lf0 = i[:, 0], lf[:, 0]                                # (B,H)
    m_new = jnp.maximum(lf0 + state["m"], i0)
    fg = jnp.exp(lf0 + state["m"] - m_new)
    ig = jnp.exp(i0 - m_new)
    C = fg[:, :, None, None] * state["C"] + \
        ig[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k0, v0)
    n = fg[:, :, None] * state["n"] + ig[:, :, None] * k0
    num = jnp.einsum("bhd,bhde->bhe", q0, C)
    den = jnp.einsum("bhd,bhd->bh", q0, n)
    h_out = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).astype(u.dtype)
    gate = jax.nn.silu(u @ p["w_gate"])
    out = h_out.reshape(u.shape[0], 1, -1) * gate
    out = out @ p["w_out"]
    return out, {"C": C, "n": n, "m": m_new}


# ======================================================================= sLSTM
def init_slstm(key, cfg: ModelConfig) -> Dict:
    dt = _pdt(cfg)
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    hid = h * dh
    ks = jax.random.split(key, 3)
    w = (jax.random.normal(ks[0], (d, 4 * hid), jnp.float32)
         / math.sqrt(d)).astype(jnp.float32)
    r = (jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32)
         / math.sqrt(dh)).astype(jnp.float32)
    b = jnp.zeros((4 * hid,), jnp.float32).at[2 * hid:3 * hid].set(3.0)
    return {"w": w, "r": r, "b": b,
            "w_out": dense_init(ks[2], hid, d, dt)}


def _slstm_cell(p, cfg, xw_t, carry):
    """One timestep. xw_t: (B,4*hid) precomputed input projection."""
    h_, c_, n_, m_ = carry                                     # h: (B,H,Dh)
    hd = cfg.n_heads * cfg.d_head
    rec = jnp.einsum("bhd,ghde->bghe", h_, p["r"])             # (B,4,H,Dh)
    pre = xw_t.reshape(-1, 4, cfg.n_heads, cfg.d_head) + rec + \
        p["b"].reshape(4, cfg.n_heads, cfg.d_head)
    pz, pi, pf, po = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(pz)
    o = jax.nn.sigmoid(po)
    lf = jax.nn.log_sigmoid(pf)
    m_new = jnp.maximum(lf + m_, pi)
    ig = jnp.exp(pi - m_new)
    fg = jnp.exp(lf + m_ - m_new)
    c_new = fg * c_ + ig * z
    n_new = fg * n_ + ig
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    del hd
    return (h_new, c_new, n_new, m_new)


def slstm_forward(p: Dict, cfg: ModelConfig, u: jax.Array,
                  state=None, return_state: bool = False):
    """u: (B,S,d); strictly sequential scan over time."""
    b, s, _ = u.shape
    if state is None:
        state = slstm_init_state(cfg, b)
    xdt = jnp.float32 if cfg.ssm_io_f32 else u.dtype
    xw = (u.astype(xdt) @ p["w"].astype(xdt))                  # (B,S,4hid)
    carry0 = (state["h"], state["c"], state["n"], state["m"])

    def step(carry, xw_t):
        new = _slstm_cell(p, cfg, xw_t.astype(jnp.float32), carry)
        return new, new[0]

    carry, hs = jax.lax.scan(step, carry0, xw.transpose(1, 0, 2))
    h_seq = hs.transpose(1, 0, 2, 3).reshape(b, s, -1).astype(u.dtype)
    out = h_seq @ p["w_out"]
    if return_state:
        return out, {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    return out


def slstm_init_state(cfg: ModelConfig, batch: int) -> Dict:
    shp = (batch, cfg.n_heads, cfg.d_head)
    return {"h": jnp.zeros(shp, jnp.float32), "c": jnp.zeros(shp, jnp.float32),
            "n": jnp.zeros(shp, jnp.float32),
            "m": jnp.full(shp, -1e30, jnp.float32)}


def slstm_step(p: Dict, cfg: ModelConfig, u: jax.Array, state: Dict):
    """u: (B,1,d)."""
    xw = (u[:, 0].astype(jnp.float32) @ p["w"].astype(jnp.float32))
    carry = (state["h"], state["c"], state["n"], state["m"])
    h_new, c_new, n_new, m_new = _slstm_cell(p, cfg, xw, carry)
    out = (h_new.reshape(u.shape[0], -1).astype(u.dtype) @ p["w_out"])[:, None]
    return out, {"h": h_new, "c": c_new, "n": n_new, "m": m_new}
