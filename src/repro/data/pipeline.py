"""Deterministic host-sharded synthetic token pipeline with prefetch.

Every (step, dp_rank) pair maps to a unique RNG stream, so any elastic
re-mesh (different DP degree) replays EXACTLY the same global batch order —
a worker that restarts or a job that rescales never skips or repeats data.
Documents are variable-length with EOS separators; targets are next-token.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    mean_doc_len: int = 256
    eos_id: int = 1


def _batch_rng(cfg: DataConfig, step: int, sample: int) -> np.random.RandomState:
    # stable per-(step, global sample index) stream
    return np.random.RandomState((cfg.seed * 1_000_003 + step * 65_537 +
                                  sample) % (2 ** 31 - 1))


def sample_tokens(dcfg: DataConfig, mcfg: ModelConfig, step: int,
                  sample: int, seq_len: int) -> np.ndarray:
    """One sequence of packed synthetic documents."""
    rng = _batch_rng(dcfg, step, sample)
    out = np.empty(seq_len + 1, np.int32)
    pos = 0
    while pos < seq_len + 1:
        dlen = max(8, int(rng.exponential(dcfg.mean_doc_len)))
        dlen = min(dlen, seq_len + 1 - pos)
        # zipf-ish unigram stream over the real vocab
        toks = rng.zipf(1.3, dlen).astype(np.int64) % (mcfg.raw_vocab_size - 2)
        out[pos:pos + dlen] = toks + 2
        pos += dlen
        if pos < seq_len + 1:
            out[pos] = dcfg.eos_id
            pos += 1
    return out


def global_batch(dcfg: DataConfig, mcfg: ModelConfig, shape: ShapeConfig,
                 step: int, *, dp_rank: int = 0, dp_size: int = 1,
                 seq_len: Optional[int] = None) -> Dict[str, np.ndarray]:
    """The dp_rank'th shard of the step's global batch (tokens + targets)."""
    s = seq_len if seq_len is not None else shape.seq_len
    if mcfg.family == "vlm":
        s = s - mcfg.n_patches
    b_global = shape.global_batch
    assert b_global % dp_size == 0
    b_local = b_global // dp_size
    tok = np.stack([
        sample_tokens(dcfg, mcfg, step, dp_rank * b_local + i, s)
        for i in range(b_local)])
    batch = {"tokens": tok[:, :-1], "targets": tok[:, 1:]}
    if mcfg.family == "audio":
        rng = _batch_rng(dcfg, step, 10_000_000 + dp_rank)
        batch["frames"] = rng.randn(b_local, mcfg.enc_frames,
                                    mcfg.d_model).astype(np.float32) * 0.1
    if mcfg.family == "vlm":
        rng = _batch_rng(dcfg, step, 20_000_000 + dp_rank)
        batch["patches"] = rng.randn(b_local, mcfg.n_patches,
                                     mcfg.d_model).astype(np.float32) * 0.1
    return batch


class PrefetchLoader:
    """Background-thread prefetch of the deterministic stream."""

    def __init__(self, dcfg: DataConfig, mcfg: ModelConfig, shape: ShapeConfig,
                 *, start_step: int = 0, dp_rank: int = 0, dp_size: int = 1,
                 depth: int = 2, seq_len: Optional[int] = None):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = global_batch(dcfg, mcfg, shape, step, dp_rank=dp_rank,
                                 dp_size=dp_size, seq_len=seq_len)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
