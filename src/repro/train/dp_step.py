"""Explicit data-parallel train step with int8+error-feedback gradient
all-reduce (compression.py), built on shard_map.

The GSPMD path (train.make_train_step) lets XLA generate its own reduction
collectives; this variant takes manual control of the DP axis so the grad
all-reduce payload can be quantized — the trick that matters when the DP
axis spans pods (DCI bandwidth << ICI).  Params are replicated across the
DP axis here (pure DP; compose with TP by nesting meshes).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.train.compression import init_error_state, psum_compressed_tree
from repro.train.optimizer import AdamWConfig, adamw_update
from repro.train.train import loss_fn


def make_dp_train_step(cfg: ModelConfig, opt: AdamWConfig, mesh: Mesh,
                       axis: str = "data", compress: bool = True):
    """Returns (step_fn, init_extra_state).

    step_fn(state, err_state, batch) -> (state, err_state, metrics); the
    batch's leading dim is sharded over `axis`, params/opt replicated.
    """

    def body(state, err, batch):
        params = state["params"]

        def local_loss(p):
            return loss_fn(p, cfg, batch)

        (loss, parts), grads = jax.value_and_grad(
            local_loss, has_aux=True)(params)
        if compress:
            grads, err = psum_compressed_tree(grads, err, axis)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, axis), grads)
        loss = jax.lax.pmean(loss, axis)
        parts = jax.tree_util.tree_map(lambda x: jax.lax.pmean(x, axis),
                                       parts)
        new_params, new_opt, om = adamw_update(params, grads, state["opt"],
                                               opt)
        return ({"params": new_params, "opt": new_opt}, err,
                {"loss": loss, **parts, **om})

    replicated = P()
    sharded = P(axis)

    def batch_spec(tree):
        return jax.tree_util.tree_map(lambda _: sharded, tree)

    def step_fn(state, err_state, batch):
        from repro.train.shard_compat import shard_map
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree_util.tree_map(lambda _: replicated, state),
                      jax.tree_util.tree_map(lambda _: replicated, err_state),
                      batch_spec(batch)),
            out_specs=(jax.tree_util.tree_map(lambda _: replicated, state),
                       jax.tree_util.tree_map(lambda _: replicated,
                                              err_state),
                       replicated))
        return fn(state, err_state, batch)

    def init_extra(params) -> Dict:
        return init_error_state(params)

    return step_fn, init_extra
