"""Sharded, atomic checkpointing with resharding restore.

Layout: <dir>/step_<N>/
  manifest.msgpack   {path -> {shape, dtype, file}}, step, metadata
  <leaf files>.npy   one per pytree leaf (host-gathered)

Writes go to a tmp dir + atomic rename, so a crash mid-save never corrupts
the latest checkpoint; restore loads into ANY mesh/sharding (elastic re-mesh:
leaves are device_put with the new sharding).  On a real multi-host pod each
host writes its owned shards; here (single process) the gather is trivial —
the layout and manifest are designed for that extension (DESIGN.md §4).
"""
from __future__ import annotations

import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree,
                    metadata: Optional[Dict] = None) -> str:
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                         # atomic publish
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    base = Path(directory)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")
             if (p / "manifest.msgpack").exists()]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like,
                       step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like``; optional resharding."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    src = Path(directory) / f"step_{step:08d}"
    manifest = msgpack.unpackb((src / "manifest.msgpack").read_bytes(),
                               strict_map_key=False)
    flat_struct = _flatten(tree_like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, leaf in flat_struct.items():
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(src / info["file"])
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        if key in flat_sh and flat_sh[key] is not None:
            out[key] = jax.device_put(arr, flat_sh[key])
        else:
            out[key] = jnp.asarray(arr)
    # unflatten by path using tree_like's structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    keys_in_order = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in leaves_paths[0]]
    restored = jax.tree_util.tree_unflatten(
        leaves_paths[1], [out[k] for k in keys_in_order])
    return restored, manifest["step"], manifest["metadata"]


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    base = Path(directory)
    steps = sorted(p for p in base.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p)
