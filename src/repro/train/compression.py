"""Gradient compression for the DP all-reduce: int8 quantization with error
feedback (distributed-optimization trick; DESIGN.md §4).

Two-phase shared-scale scheme (the standard correct form):
  1. pmax the per-replica |g|_max over the DP axis -> one shared scale,
  2. quantize to int8, psum in int32, dequantize, divide by replica count.
The quantization residual is carried in an error-feedback buffer so the bias
vanishes over steps (EF-SGD).  ``psum_compressed`` is used inside a shard_map
over the DP axis (see train/dp_step.py and tests); payload shrinks ~3.97x
(int8 + one scale scalar per tensor vs f32).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_compressed(g: jax.Array, err: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Mean-reduce ``g`` over the named DP axis in int8; returns
    (reduced grad, new error-feedback buffer).  Call under shard_map/pmap."""
    g_corr = g.astype(jnp.float32) + err
    local_max = jnp.max(jnp.abs(g_corr))
    scale = jax.lax.pmax(local_max, axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = quantize(g_corr, scale)
    new_err = g_corr - dequantize(q, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n, new_err


def psum_compressed_tree(grads, err_state, axis_name: str):
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [psum_compressed(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    g_new = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    e_new = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return g_new, e_new


def init_error_state(params) -> Dict:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(params) -> float:
    """Bytes saved vs f32 all-reduce: int8 payload + one f32 scale/tensor."""
    total_f32 = sum(l.size * 4 for l in jax.tree_util.tree_leaves(params))
    total_c = sum(l.size * 1 + 4 for l in jax.tree_util.tree_leaves(params))
    return total_f32 / total_c
