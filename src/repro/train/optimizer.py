"""AdamW + clipping + LR schedules, from scratch (no optax in this image).

Moments are stored in ``cfg.opt_dtype`` (bf16 for arctic-480b per DESIGN.md §6)
and shard exactly like their parameters (ZeRO: the launcher maps both through
the same path rules).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(opt: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, opt.warmup_steps))
    prog = jnp.clip((step - opt.warmup_steps) /
                    max(1, opt.total_steps - opt.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    scale = opt.min_lr_ratio + (1.0 - opt.min_lr_ratio) * cos
    return opt.lr * warm * scale


def init_opt_state(params, opt_dtype: str) -> Dict:
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[opt_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt_state: Dict, opt: AdamWConfig
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9))
    lr = lr_at(opt, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - opt.b1 ** t
    bc2 = 1.0 - opt.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_f = opt.b1 * mu.astype(jnp.float32) + (1 - opt.b1) * g
        nu_f = opt.b2 * nu.astype(jnp.float32) + (1 - opt.b2) * jnp.square(g)
        mhat = mu_f / bc1
        vhat = nu_f / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        wd = opt.weight_decay if p.ndim >= 2 else 0.0   # no decay on norms/bias
        new_p = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu_f.astype(mu.dtype), nu_f.astype(nu.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    flat_nu = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
