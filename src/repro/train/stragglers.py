"""Straggler detection + mitigation hooks for the elastic runtime.

Per-worker-group heartbeats (step completion times) are tracked in rolling
windows; groups whose step time exceeds a robust threshold (median +
k * MAD) are flagged.  The detector feeds two consumers:

  1. Enel's metric vector — ``straggler_severity`` raises the step-time
     jitter metric so the runtime prediction (eq. 4) reflects the slowdown
     and the scaler reacts (scale out / re-mesh around the slow group).
  2. The elastic trainer — ``should_replace`` triggers checkpoint/re-mesh
     exactly like a failure, evicting the slow group (the standard
     large-fleet mitigation: replace, don't wait).
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class StragglerConfig:
    window: int = 16              # heartbeats kept per group
    mad_k: float = 5.0            # flag threshold: median + k * MAD
    min_heartbeats: int = 4
    replace_after: int = 3        # consecutive flags before eviction


class StragglerDetector:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self._beats: Dict[int, Deque[float]] = defaultdict(
            lambda: deque(maxlen=self.cfg.window))
        self._flags: Dict[int, int] = defaultdict(int)

    def heartbeat(self, group: int, step_seconds: float) -> None:
        self._beats[group].append(float(step_seconds))

    # ------------------------------------------------------------- analysis
    def _stats(self) -> Tuple[float, float]:
        """Robust (median, MAD) over each group's recent median."""
        meds = [float(np.median(b)) for b in self._beats.values()
                if len(b) >= self.cfg.min_heartbeats]
        if len(meds) < 2:
            return float("nan"), float("nan")
        med = float(np.median(meds))
        mad = float(np.median(np.abs(np.array(meds) - med))) + 1e-9
        return med, mad

    def flagged(self) -> List[int]:
        med, mad = self._stats()
        if np.isnan(med):
            return []
        out = []
        for g, b in self._beats.items():
            if len(b) < self.cfg.min_heartbeats:
                continue
            if float(np.median(b)) > med + self.cfg.mad_k * mad:
                out.append(g)
        for g in list(self._flags):
            if g not in out:
                self._flags[g] = 0
        for g in out:
            self._flags[g] += 1
        return out

    def should_replace(self) -> List[int]:
        self.flagged()
        return [g for g, n in self._flags.items()
                if n >= self.cfg.replace_after]

    def severity(self, group: Optional[int] = None) -> float:
        """Normalized slowdown of the worst (or given) group vs the median —
        plugs into Enel's metric vector as step-time jitter."""
        med, mad = self._stats()
        if np.isnan(med) or med <= 0:
            return 0.0
        groups = [group] if group is not None else list(self._beats)
        worst = 0.0
        for g in groups:
            b = self._beats.get(g)
            if b and len(b) >= self.cfg.min_heartbeats:
                worst = max(worst, (float(np.median(b)) - med) / med)
        return max(0.0, worst)
