"""Elastic training runtime driven by Enel (beyond-paper integration).

The trainer treats a training job as an iterative dataflow: every
``steps_per_component`` optimizer steps form one *component* whose stages
(data-load, step-compute, checkpoint) are timed and attributed exactly like
the paper's Spark task sets.  At each component boundary Enel predicts the
remaining runtime for every candidate DP degree and the trainer elastically
re-meshes (checkpoint -> new mesh -> resharded restore) when the runtime
target demands it.  Simulated worker failures shrink the DP degree and
restart from the latest checkpoint — the paper's §V-B.4 scenario mapped onto
SPMD training.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.graph import ComponentGraph, NodeAttrs, build_graph
from repro.core.scaling import EnelScaler
from repro.core.training import EnelTrainer
from repro.core.autoencoder import embed_properties, train_autoencoder
from repro.core.encoding import encode_properties
from repro.data.pipeline import DataConfig, global_batch
from repro.launch.mesh import make_mesh
from repro.launch.shardings import (batch_shardings, logical_rules,
                                    state_shardings)
from repro.models.sharding import use_rules
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.train import init_train_state, make_train_step


class TrainContextEncoder:
    """Context vectors for training-stage nodes (same encoding substrate)."""

    def __init__(self, cfg: ModelConfig, seed: int = 0):
        self.cfg = cfg
        props = self._base_props() + ["data-load", "train-step", "checkpoint"]
        self.ae, _ = train_autoencoder(encode_properties(props), steps=200,
                                       seed=seed)
        self._cache: Dict[str, np.ndarray] = {}

    def _base_props(self) -> List:
        c = self.cfg
        return [c.name, c.family, int(c.n_layers), int(c.d_model),
                int(c.n_heads), "tpu v5e", int(c.vocab_size)]

    def context(self, stage: str, dp: int) -> np.ndarray:
        key = f"{stage}:{dp}"
        if key not in self._cache:
            u = embed_properties(self.ae, encode_properties(
                self._base_props())).mean(0)
            v = embed_properties(self.ae, encode_properties(
                ["jax", "xla"])).mean(0)
            w = embed_properties(self.ae, encode_properties(
                [stage, int(dp)])).mean(0)
            self._cache[key] = np.concatenate([u, v, w]).astype(np.float32)
        return self._cache[key]


@dataclass
class ElasticConfig:
    target_runtime: float                  # seconds for the whole job
    n_components: int = 6
    steps_per_component: int = 4
    dp_choices: Tuple[int, ...] = (1, 2, 4, 8)
    tp: int = 1
    ckpt_dir: str = "/tmp/repro_elastic_ckpt"
    ckpt_every_components: int = 1
    fail_at_component: Optional[int] = None  # simulated worker-group loss
    seed: int = 0


@dataclass
class ComponentLog:
    comp_idx: int
    dp: int
    runtime: float
    stage_times: Dict[str, float]
    rescaled_from: Optional[int] = None
    failed: bool = False


class ElasticTrainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 ecfg: ElasticConfig, opt: Optional[AdamWConfig] = None):
        self.cfg = cfg
        self.shape = shape
        self.ecfg = ecfg
        self.opt = opt or AdamWConfig(warmup_steps=2, total_steps=200)
        self.dcfg = DataConfig(seed=ecfg.seed)
        self.encoder = TrainContextEncoder(cfg, seed=ecfg.seed)
        self.enel = EnelTrainer(seed=ecfg.seed)
        self.scaler = EnelScaler(self.enel,
                                 (min(ecfg.dp_choices), max(ecfg.dp_choices)))
        self.logs: List[ComponentLog] = []
        self.graphs: List[ComponentGraph] = []
        self.global_step = 0
        self._mesh = None
        self._step_fn = None
        self._state = None
        self._dp = max(ecfg.dp_choices)

    # -------------------------------------------------------------- re-mesh
    def _build(self, dp: int, restore_from: Optional[str] = None) -> None:
        """(Re)build mesh + jitted step; optionally restore (resharded)."""
        ecfg = self.ecfg
        self._dp = dp
        self._mesh = make_mesh(dp, ecfg.tp)
        rules = logical_rules(self.cfg, self._mesh, self.shape)
        self._rules = rules
        with self._mesh, use_rules(self._mesh, rules):
            if self._state is None:
                state = init_train_state(jax.random.PRNGKey(ecfg.seed),
                                         self.cfg, self.opt)
            else:
                state = self._state      # host copies; re-placed below
            ssh = state_shardings(self.cfg, self._mesh, state)
            if restore_from is not None:
                state, _, _ = restore_checkpoint(restore_from, state,
                                                 shardings=ssh)
            else:
                state = jax.device_put(state, ssh)
            self._state = state
            step = make_train_step(self.cfg, self.opt)
            self._step_fn = jax.jit(
                step, in_shardings=(ssh, None),
                out_shardings=(ssh, NamedSharding(self._mesh, P())),
                donate_argnums=0)

    # ------------------------------------------------------------ components
    def _run_component(self, comp_idx: int,
                       rescaled_from: Optional[int]) -> ComponentLog:
        ecfg = self.ecfg
        t_data = t_step = 0.0
        losses = []
        with self._mesh, use_rules(self._mesh, self._rules):
            for _ in range(ecfg.steps_per_component):
                t0 = time.time()
                batch = global_batch(self.dcfg, self.cfg, self.shape,
                                     self.global_step,
                                     seq_len=self.shape.seq_len)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t_data += time.time() - t0
                t0 = time.time()
                self._state, metrics = self._step_fn(self._state, batch)
                jax.block_until_ready(metrics["loss"])
                t_step += time.time() - t0
                losses.append(float(metrics["loss"]))
                self.global_step += 1
        t_ckpt = 0.0
        if comp_idx % ecfg.ckpt_every_components == 0:
            t0 = time.time()
            host_state = jax.tree_util.tree_map(np.asarray, self._state)
            save_checkpoint(ecfg.ckpt_dir, self.global_step, host_state,
                            metadata={"dp": self._dp})
            t_ckpt = time.time() - t0
        log = ComponentLog(comp_idx, self._dp, t_data + t_step + t_ckpt,
                           {"data-load": t_data, "train-step": t_step,
                            "checkpoint": t_ckpt},
                           rescaled_from=rescaled_from)
        self.logs.append(log)
        return log

    def _component_nodes(self, log: ComponentLog) -> List[NodeAttrs]:
        nodes = []
        a = float(log.rescaled_from or log.dp)
        spc = self.ecfg.steps_per_component
        for i, stage in enumerate(("data-load", "train-step", "checkpoint")):
            t = log.stage_times[stage]
            thr = spc / max(log.stage_times["train-step"], 1e-3)
            metrics = np.array([
                min(1.0, thr / 10.0),                  # throughput proxy
                1.0 / log.dp,                          # comm share proxy
                log.stage_times["data-load"] / max(log.runtime, 1e-6),
                0.05, 0.0], np.float32)
            nodes.append(NodeAttrs(
                name=stage, context=self.encoder.context(stage, log.dp),
                metrics=metrics, start_scaleout=a if i == 0 else log.dp,
                end_scaleout=log.dp, time_fraction=1.0, runtime=t,
                overhead=None))
        return nodes

    def _future_builder(self, comp_idx: int, a: float, z: float,
                        preds: List[NodeAttrs]) -> ComponentGraph:
        nodes = []
        for i, stage in enumerate(("data-load", "train-step", "checkpoint")):
            nodes.append(NodeAttrs(
                name=stage, context=self.encoder.context(stage, int(z)),
                metrics=None, start_scaleout=a if i == 0 else z,
                end_scaleout=z, time_fraction=1.0 if a == z else 0.8))
        n = len(nodes)
        edges = [(i, i + 1) for i in range(n - 1)]
        edges += [(n + j, 0) for j in range(len(preds))]
        return build_graph(nodes + preds, edges, component_id=comp_idx)

    # ----------------------------------------------------------------- run
    def run(self) -> Dict:
        ecfg = self.ecfg
        self._build(self._dp)
        elapsed = 0.0
        prev_summary = None
        rescaled_from = None
        for comp_idx in range(ecfg.n_components):
            if ecfg.fail_at_component == comp_idx and self._dp > min(
                    ecfg.dp_choices):
                # simulated worker-group failure: shrink DP, restart from ckpt
                new_dp = max(d for d in ecfg.dp_choices if d < self._dp)
                rescaled_from = self._dp
                self._build(new_dp, restore_from=ecfg.ckpt_dir)
                self.logs.append(ComponentLog(comp_idx, new_dp, 0.0, {},
                                              rescaled_from, failed=True))
            log = self._run_component(comp_idx, rescaled_from)
            rescaled_from = None
            elapsed += log.runtime
            nodes = self._component_nodes(log)
            from repro.core.graph import summary_node, historical_summary
            preds = [p for p in (prev_summary,) if p is not None]
            if comp_idx > 0:
                h = historical_summary(
                    self.scaler.hist_summaries.get(comp_idx - 1, []),
                    float(self._dp))
                if h is not None:
                    preds.append(h)
            self.graphs.append(_log_graph(nodes, preds, comp_idx))
            self.scaler.record_component(comp_idx, nodes, log.runtime)
            prev_summary = summary_node(nodes, f"P{comp_idx}")
            # fine-tune + recommend
            if comp_idx < ecfg.n_components - 1:
                self.enel.observe_run(self.graphs, retrain_every=10 ** 9,
                                      steps=0, fine_tune_steps=40)
                # batched sweep engine: _future_builder's z-dependent context
                # (encoder.context(stage, int(z))) is evaluated ONCE at the
                # current dp for every candidate; only a/z/r and H-summary
                # attrs vary.  Acceptable here because dp_new snaps to the
                # coarse dp_choices grid below; use recommend_pergraph for
                # exact per-candidate contexts.
                dp_new, pred, _ = self.scaler.recommend(
                    graph_builder=self._future_builder,
                    next_comp=comp_idx + 1, n_components=ecfg.n_components,
                    elapsed=elapsed, current_scaleout=self._dp,
                    target_runtime=ecfg.target_runtime,
                    current_summary=prev_summary)
                dp_new = min(ecfg.dp_choices,
                             key=lambda d: abs(d - dp_new))   # snap to choices
                if dp_new != self._dp:
                    rescaled_from = self._dp
                    host_state = jax.tree_util.tree_map(np.asarray,
                                                        self._state)
                    save_checkpoint(ecfg.ckpt_dir, self.global_step,
                                    host_state, metadata={"dp": self._dp})
                    self._build(dp_new, restore_from=ecfg.ckpt_dir)
        return {
            "elapsed": elapsed, "target": ecfg.target_runtime,
            "met_target": elapsed <= ecfg.target_runtime,
            "dp_trace": [l.dp for l in self.logs],
            "final_step": self.global_step,
            "n_rescales": sum(1 for l in self.logs
                              if l.rescaled_from is not None),
        }


def _log_graph(nodes: List[NodeAttrs], preds: List[NodeAttrs],
               comp_idx: int) -> ComponentGraph:
    n = len(nodes)
    edges = [(i, i + 1) for i in range(n - 1)]
    edges += [(n + j, 0) for j in range(len(preds))]
    return build_graph(nodes + preds, edges, component_id=comp_idx)
