"""train_step / eval loss. Pure functions closed over (cfg, opt)."""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import apply_model, init_model
from repro.models.sharding import constrain
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

AUX_LOSS_WEIGHT = 0.01


def init_train_state(key, cfg: ModelConfig, opt: AdamWConfig) -> Dict:
    params = init_model(key, cfg)
    return {"params": params, "opt": init_opt_state(params, cfg.opt_dtype)}


def loss_fn(params, cfg: ModelConfig, batch: Dict) -> Tuple[jax.Array, Dict]:
    logits, aux = apply_model(params, cfg, batch)
    targets = batch["targets"]
    if cfg.family == "vlm":                     # loss only over text positions
        logits = logits[:, cfg.n_patches:]
    mask = ((targets >= 0) & (targets < cfg.raw_vocab_size)).astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    # GSPMD-friendly CE over the vocab-sharded axis: logsumexp + label pick
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.clip(targets, 0, cfg.vocab_size - 1)[..., None], axis=-1
    )[..., 0]
    nll = (lse - picked) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll) / denom
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux, "tokens": denom}


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    grad_accum: int = 1):
    """grad_accum > 1 scans microbatches, accumulating grads in
    cfg.grad_accum_dtype (arctic: bf16 — memory note in DESIGN.md §6)."""
    acc_dt = {"bfloat16": jnp.bfloat16,
              "float32": jnp.float32}[cfg.grad_accum_dtype]

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        if grad_accum == 1:
            (loss, parts), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        else:
            def _split(x):
                x = x.reshape(grad_accum, x.shape[0] // grad_accum,
                              *x.shape[1:])
                return constrain(x, None, "dp", *([None] * (x.ndim - 2)))

            micro = jax.tree_util.tree_map(_split, batch)

            def mb(carry, mbatch):
                gacc, lacc = carry
                (l, parts_i), g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, mbatch), has_aux=True)(params)
                gacc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (gacc, lacc + l), parts_i

            gacc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), parts_all = jax.lax.scan(
                mb, (gacc0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            parts = jax.tree_util.tree_map(lambda x: jnp.mean(x), parts_all)
        new_params, new_opt, om = adamw_update(params, grads, state["opt"], opt)
        metrics = {"loss": loss, **parts, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, parts = loss_fn(params, cfg, batch)
        return {"loss": loss, **parts}
    return eval_step
