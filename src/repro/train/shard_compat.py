"""shard_map across JAX versions: `jax.shard_map(..., check_vma=)` (new)
vs `jax.experimental.shard_map.shard_map(..., check_rep=)` (<= 0.4.x)."""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
