"""Optional pipeline parallelism: GPipe schedule via shard_map + ppermute.

Each device on the 'stage' mesh axis owns one stage's params; microbatches
stream through the 1-D pipeline with a collective_permute per tick.  This is
the PP building block advertised in DESIGN.md §4 — the 40 baseline cells use
DP x TP; PP composes for deeper-than-HBM models (e.g., arctic at dp<16).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def make_stage_params(key, n_stages: int, d: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    s = 1.0 / jnp.sqrt(d)
    return {"w1": jax.random.normal(k1, (n_stages, d, d)) * s,
            "w2": jax.random.normal(k2, (n_stages, d, d)) * s}


def stage_fn(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    return x + jnp.tanh(x @ params["w1"]) @ params["w2"]


def pipelined_forward(params: Dict[str, jax.Array], x: jax.Array,
                      mesh: Mesh, axis: str = "stage") -> jax.Array:
    """x: (n_micro, b, d) microbatches; params leaves lead with n_stages.

    Returns the full pipeline output, identical to applying the stages
    sequentially (validated in tests/test_multidevice.py)."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def body(stage_params, xs):
        local = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        idx = jax.lax.axis_index(axis)
        recv0 = jnp.zeros(xs.shape[1:], xs.dtype)
        out0 = jnp.zeros_like(xs)

        def tick(t, state):
            recv, outputs = state
            mb_in = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(idx == 0, xs[mb_in], recv)
            out = stage_fn(local, inp)
            mb_out = t - (n_stages - 1)
            valid = (idx == n_stages - 1) & (mb_out >= 0) & (mb_out < n_micro)
            written = jax.lax.dynamic_update_index_in_dim(
                outputs, out, jnp.clip(mb_out, 0, n_micro - 1), 0)
            outputs = jnp.where(valid, written, outputs)
            recv = jax.lax.ppermute(out, axis, perm)
            return recv, outputs

        _, outputs = jax.lax.fori_loop(0, ticks, tick, (recv0, out0))
        return jax.lax.psum(outputs, axis)   # non-last stages contribute 0

    from repro.train.shard_compat import shard_map
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(None, None, None)),
                   out_specs=P(None, None, None))
    return fn(params, x)
