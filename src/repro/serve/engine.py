"""Batched serving engine: prefill + decode with a sharded KV cache.

The engine drives the same ``prefill``/``decode_step`` functions the dry-run
lowers, adds continuous batching bookkeeping (one active wave; requests pad
to the wave's max prompt), greedy sampling, and per-stage timing that feeds
the Enel scaler when serving elastically (replica count = scale-out).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, pad_cache_to, prefill


@dataclass
class Request:
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def decode_tok_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt    # left-pad
        return toks

    def serve_wave(self, reqs: List[Request],
                   extras: Optional[Dict] = None) -> ServeStats:
        """One continuous-batching wave: joint prefill, lockstep decode."""
        stats = ServeStats()
        toks = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        stats.prefill_s = time.time() - t0

        pos = toks.shape[1]
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in reqs)
        t0 = time.time()
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done and step < r.max_new_tokens:
                    r.out_tokens.append(int(next_tok[i, 0]))
                    stats.tokens_out += 1
            if pos + 1 >= self.max_len:
                break
            logits, cache = self._decode(self.params, cache, next_tok,
                                         jnp.int32(pos))
            next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            pos += 1
        jax.block_until_ready(next_tok)
        stats.decode_s = time.time() - t0
        for r in reqs:
            r.done = True
        return stats
