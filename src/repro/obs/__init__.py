"""Controller-wide observability: unified metrics registry + flight
recorder, gated by ``ENEL_OBS`` (default on; ``ENEL_OBS=0`` disables).

Contract: with observability disabled, decisions are bit-exact vs the
uninstrumented controller and compile counts are unchanged — span
emission and histogram observation no-op, and the fused campaign plan
carries ``telemetry=False`` so its jaxpr is identical. Registry-backed
*counters* stay live either way: they are host-side and feed no
decision, and existing attribute APIs (``service.retries`` etc.) must
keep working regardless of the flag.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Dict, Optional

from .metrics import (DEFAULT_LATENCY_BUCKETS, CounterSeries, GaugeSeries,
                      HistogramSeries, Metric, MetricsRegistry)
from .recorder import FlightRecorder

_ENABLED = os.environ.get("ENEL_OBS", "1").lower() in ("1", "true", "yes")

REGISTRY = MetricsRegistry()
RECORDER = FlightRecorder(capacity=int(os.environ.get("ENEL_OBS_RING", "4096")),
                          gate=lambda: _ENABLED)


def enabled(override: Optional[bool] = None) -> bool:
    return _ENABLED if override is None else bool(override)


def set_enabled(value: bool) -> bool:
    """Flip the gate; returns the previous value (for try/finally)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(value)
    return prev


@contextmanager
def obs_enabled(value: bool = True):
    prev = set_enabled(value)
    try:
        yield
    finally:
        set_enabled(prev)


def registry() -> MetricsRegistry:
    return REGISTRY


def recorder() -> FlightRecorder:
    return RECORDER


def emit(_kind: str, _ts: Optional[float] = None, **attrs) -> int:
    """Emit a span into the global flight recorder (no-op when gated)."""
    return RECORDER.emit(_kind, _ts=_ts, **attrs)


def observe(name: str, value: float, **labels) -> None:
    """Observe ``value`` into histogram ``name`` (no-op when disabled)."""
    if _ENABLED:
        REGISTRY.histogram(name).labels(**labels).observe(value)


def snapshot() -> Dict:
    """Combined pickle-safe obs state for campaign checkpoints."""
    return {"metrics": REGISTRY.snapshot(), "recorder": RECORDER.state()}


def restore(state: Optional[Dict]) -> None:
    if not state:
        return
    REGISTRY.restore(state.get("metrics", {}))
    if "recorder" in state:
        RECORDER.load(state["recorder"])


def reset() -> None:
    """Clear all global obs state (test isolation)."""
    REGISTRY.reset()
    RECORDER.clear()
