"""Flight recorder: a bounded ring of structured span events.

Spans are plain dicts ``{"seq", "ts", "kind", "attrs"}``. ``seq`` is a
monotonic index (causal links between spans reference it — e.g. a
``decision.fallback`` span carries ``cause_seq`` pointing at the
guardrail/timeout/breaker event that forced it). ``ts`` is wall time
for live spans and a *logical* timestamp (sim clock) for spans replayed
from fused-campaign telemetry, so fused and stepped replays of the same
plan produce identical streams modulo ``seq``/``ts`` — parity compares
``(kind, attrs)``.

The ring is bounded (default 4096 spans): old spans fall off, the
recorder never grows without bound inside long campaigns.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional


class FlightRecorder:
    def __init__(self, capacity: int = 4096,
                 gate: Optional[Callable[[], bool]] = None):
        self.capacity = int(capacity)
        self.gate = gate            # None -> always on
        self._ring: deque = deque(maxlen=self.capacity)
        self._seq = 0
        self.dropped = 0            # spans evicted from the ring

    # -- emission -----------------------------------------------------

    def emit(self, _kind: str, _ts: Optional[float] = None, **attrs) -> int:
        """Append a span; returns its seq (-1 when gated off).

        The positional params are underscore-prefixed so span attrs named
        ``kind``/``ts`` (e.g. a run's scaler kind) stay usable as kwargs.
        """
        if self.gate is not None and not self.gate():
            return -1
        if _ts is None:
            import time
            _ts = time.time()
        seq = self._seq
        self._seq += 1
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append({"seq": seq, "ts": float(_ts), "kind": str(_kind),
                           "attrs": attrs})
        return seq

    # -- queries ------------------------------------------------------

    def events(self, kind: Optional[str] = None) -> List[Dict]:
        """Spans oldest-first; ``kind`` may be an exact kind or a
        ``"prefix."``-style prefix (trailing dot)."""
        if kind is None:
            return list(self._ring)
        if kind.endswith("."):
            return [e for e in self._ring if e["kind"].startswith(kind)]
        return [e for e in self._ring if e["kind"] == kind]

    def find(self, seq: int) -> Optional[Dict]:
        for e in self._ring:
            if e["seq"] == seq:
                return e
        return None

    def stream(self) -> List[tuple]:
        """(kind, attrs) pairs — the seq/ts-free view parity tests use."""
        return [(e["kind"], e["attrs"]) for e in self._ring]

    def __len__(self) -> int:
        return len(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self._seq = 0
        self.dropped = 0

    # -- snapshot / restore (pickle-safe) -----------------------------

    def state(self) -> Dict:
        return {"capacity": self.capacity, "seq": self._seq,
                "dropped": self.dropped,
                "ring": [dict(e, attrs=dict(e["attrs"])) for e in self._ring]}

    def load(self, state: Dict) -> None:
        self.capacity = int(state.get("capacity", self.capacity))
        self._ring = deque((dict(e, attrs=dict(e["attrs"]))
                            for e in state.get("ring", ())),
                           maxlen=self.capacity)
        self._seq = int(state.get("seq", len(self._ring)))
        self.dropped = int(state.get("dropped", 0))

    # -- exporters ----------------------------------------------------

    def to_jsonl(self, path: Optional[str] = None) -> str:
        """JSONL export (one span per line); writes ``path`` if given."""
        text = "\n".join(json.dumps(e, sort_keys=True, default=str)
                         for e in self._ring)
        if text:
            text += "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def span_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self._ring:
            out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out
