"""Unified metrics registry: typed counters, gauges, and fixed-bucket
latency histograms with labeled families.

Design constraints (ISSUE 9):

* **No sample storage.** Histograms keep only per-bucket counts plus
  exact sum/count/min/max, so p50/p95/p99 are derivable by linear
  interpolation inside the owning bucket — memory is O(buckets) no
  matter how many observations land.
* **Pickle-safe snapshots.** ``snapshot()`` returns plain dicts/tuples
  so ``CampaignCheckpoint``/``FusedCheckpoint`` can embed registry state
  and restore it trace-identically. ``restore`` merges: series present
  in the snapshot are overwritten, series created since are left alone
  (a checkpoint from campaign A must not clobber campaign B's metrics).
* **Attribute-API compatibility.** Existing scattered counters
  (service robustness counters, template-cache stats, trainer skip
  counters) re-register here behind their current attribute APIs via
  ``CounterSeries``/``GaugeSeries`` handles that support ``+=``-style
  read-modify-write through properties.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Log-spaced seconds ladder: 0.1 ms .. 30 s. Covers both per-request
# decision latencies (sub-ms at fleet scale) and scratch fits (seconds).
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class CounterSeries:
    """One labeled counter time series. Monotonic by convention, but
    ``set`` exists so checkpoint restore can rewind trace-identically."""

    __slots__ = ("_value",)

    def __init__(self, value: float = 0.0):
        self._value = value

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def set(self, value: float) -> None:
        self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def state(self) -> float:
        return self._value

    def load(self, state: float) -> None:
        self._value = float(state)


class GaugeSeries:
    __slots__ = ("_value",)

    def __init__(self, value: float = 0.0):
        self._value = value

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def state(self) -> float:
        return self._value

    def load(self, state: float) -> None:
        self._value = float(state)


class HistogramSeries:
    """Fixed-bucket histogram: per-bucket counts + sum/count/min/max.

    ``buckets`` are inclusive upper bounds; an implicit +inf bucket
    catches the overflow. Quantiles interpolate linearly inside the
    owning bucket and are clamped to the observed [min, max] so p99 of
    three samples never reports a bucket edge wildly past the data.
    """

    __slots__ = ("buckets", "counts", "sum", "count", "vmin", "vmax")

    def __init__(self, buckets: Tuple[float, ...]):
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        if not math.isfinite(v):
            return
        i = 0
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.sum += v
        self.count += 1
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return float("nan")
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.buckets[i - 1] if i > 0 else min(self.vmin, self.buckets[0])
                hi = self.buckets[i] if i < len(self.buckets) else self.vmax
                frac = (target - cum) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return max(self.vmin, min(self.vmax, est))
            cum += c
        return self.vmax

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.vmin if self.count else float("nan"),
            "max": self.vmax if self.count else float("nan"),
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }

    def state(self) -> Dict[str, object]:
        return {"counts": list(self.counts), "sum": self.sum,
                "count": self.count, "vmin": self.vmin, "vmax": self.vmax}

    def load(self, state: Dict[str, object]) -> None:
        self.counts = list(state["counts"])
        self.sum = float(state["sum"])
        self.count = int(state["count"])
        self.vmin = float(state["vmin"])
        self.vmax = float(state["vmax"])


_SERIES_CLS = {"counter": CounterSeries, "gauge": GaugeSeries,
               "histogram": HistogramSeries}


class Metric:
    """A named family of labeled series of one kind."""

    def __init__(self, name: str, kind: str, help: str = "",
                 buckets: Optional[Tuple[float, ...]] = None):
        if kind not in _SERIES_CLS:
            raise ValueError(f"unknown metric kind {kind!r}")
        self.name = name
        self.kind = kind
        self.help = help
        self.buckets = tuple(buckets) if buckets else None
        self._series: Dict[LabelKey, object] = {}

    def labels(self, **labels):
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            if self.kind == "histogram":
                s = HistogramSeries(self.buckets or DEFAULT_LATENCY_BUCKETS)
            else:
                s = _SERIES_CLS[self.kind]()
            self._series[key] = s
        return s

    def series(self) -> Dict[LabelKey, object]:
        return self._series

    def drop(self, **labels) -> None:
        self._series.pop(_label_key(labels), None)

    def state(self) -> Dict[str, object]:
        # label keys serialize as JSON strings so the whole snapshot is
        # both pickle- AND json-safe (checkpoints pickle it; artifact
        # dumps json it)
        return {"kind": self.kind, "help": self.help,
                "buckets": list(self.buckets) if self.buckets else None,
                "series": {json.dumps(k): s.state()
                           for k, s in self._series.items()}}

    def load(self, state: Dict[str, object]) -> None:
        for key, st in state.get("series", {}).items():
            if isinstance(key, str):
                key = json.loads(key)
            key = tuple(tuple(p) for p in key)
            s = self._series.get(key)
            if s is None:
                if self.kind == "histogram":
                    s = HistogramSeries(self.buckets or DEFAULT_LATENCY_BUCKETS)
                else:
                    s = _SERIES_CLS[self.kind]()
                self._series[key] = s
            s.load(st)


class MetricsRegistry:
    """Controller-wide registry. ``counter``/``gauge``/``histogram`` are
    idempotent by name (re-registration returns the existing family,
    kind-checked), so every subsystem can declare its instruments at
    import/construction time without coordination."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, kind: str, help: str,
             buckets: Optional[Tuple[float, ...]] = None) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            if help and not m.help:
                m.help = help
            return m
        m = Metric(name, kind, help, buckets)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Metric:
        return self._get(name, "counter", help)

    def gauge(self, name: str, help: str = "") -> Metric:
        return self._get(name, "gauge", help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Tuple[float, ...]] = None) -> Metric:
        return self._get(name, "histogram", help,
                         buckets or DEFAULT_LATENCY_BUCKETS)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return list(self._metrics)

    def reset(self) -> None:
        self._metrics.clear()

    # -- snapshot / restore (pickle-safe: dicts, tuples, floats only) --

    def snapshot(self, prefix: Optional[str] = None) -> Dict[str, object]:
        return {name: m.state() for name, m in self._metrics.items()
                if prefix is None or name.startswith(prefix)}

    def restore(self, snap: Dict[str, object]) -> None:
        """Merge-restore: overwrite series present in ``snap``; series
        and metrics created since the snapshot are left untouched."""
        for name, st in (snap or {}).items():
            m = self._get(name, st.get("kind", "counter"),
                          st.get("help", ""), st.get("buckets"))
            m.load(st)

    # -- exporters ----------------------------------------------------

    def rows(self, prefix: Optional[str] = None) -> List[Dict[str, object]]:
        """Flatten to JSON-friendly rows for bench artifacts/reports."""
        out: List[Dict[str, object]] = []
        for name, m in sorted(self._metrics.items()):
            if prefix is not None and not name.startswith(prefix):
                continue
            for key, s in sorted(m.series().items()):
                row: Dict[str, object] = {"metric": name, "kind": m.kind,
                                          "labels": dict(key)}
                if m.kind == "histogram":
                    row.update(s.summary())
                else:
                    row["value"] = s.value
                out.append(row)
        return out

    def prometheus_text(self) -> str:
        """Prometheus exposition format (text/plain; version 0.0.4)."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, s in sorted(m.series().items()):
                if m.kind == "histogram":
                    cum = 0
                    for i, ub in enumerate(s.buckets):
                        cum += s.counts[i]
                        lk = _label_key(dict(key, le=_fmt_le(ub)))
                        lines.append(f"{name}_bucket{_label_str(lk)} {cum}")
                    lk = _label_key(dict(key, le="+Inf"))
                    lines.append(f"{name}_bucket{_label_str(lk)} {s.count}")
                    lines.append(f"{name}_sum{_label_str(key)} {s.sum}")
                    lines.append(f"{name}_count{_label_str(key)} {s.count}")
                else:
                    lines.append(f"{name}{_label_str(key)} {s.value}")
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt_le(ub: float) -> str:
    return f"{ub:g}"
