"""Post-compile HLO analysis: collective operand bytes + roofline terms.

``cost_analysis()`` gives FLOPs and bytes but not collective traffic, so we
parse the compiled (SPMD-partitioned, per-device) HLO text and sum operand
sizes of every collective op, bucketed by op kind.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(typestr: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(typestr))


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, operand_bytes} from partitioned HLO text."""
    # symbol table: op name -> result bytes (covers operand lookups)
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        eq = rhs.split("(")[0]  # type portion before the op call
        sizes[name] = _result_bytes(eq)

    stats = {k: {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0}
             for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        _, rhs = m.groups()
        for kind in COLLECTIVES:
            # match `kind(` or `kind-start(`; skip -done (double count)
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                call = rhs.split(f"{kind}-start(")[-1] if f"{kind}-start(" in rhs \
                    else rhs.split(f"{kind}(")[-1]
                inline = _SHAPE_RE.findall(call.split(")")[0])
                if inline:
                    ob = sum(_shape_bytes(dt, dims) for dt, dims in inline)
                else:
                    ops = _OPND_RE.findall(call.split(")")[0])
                    ob = sum(sizes.get(o, 0) for o in ops)
                stats[kind]["count"] += 1
                stats[kind]["operand_bytes"] += ob
                stats[kind]["result_bytes"] += _result_bytes(
                    rhs.split(kind)[0])
                break
    return stats


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["operand_bytes"] for v in stats.values())


# ------------------------------------------------------------------ roofline
PEAK_FLOPS = 197e12        # TPU v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    """Three per-step time terms in seconds (per-device program view)."""
    return {
        "t_compute": flops_per_device / PEAK_FLOPS,
        "t_memory": bytes_per_device / HBM_BW,
        "t_collective": coll_bytes_per_device / LINK_BW,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("t_compute", "t_memory", "t_collective"),
               key=lambda k: terms[k])
