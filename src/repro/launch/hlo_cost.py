"""HLO-text cost model with while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, which makes
scan-over-layers models look ~n_layers cheaper than they are.  This module
parses the partitioned HLO text into its computation call graph and computes:

  * flops        — 2 * prod(result dims) * prod(contracting dims) per dot,
                   multiplied through while trip counts (fusion-recursive)
  * hbm_bytes    — per top-level op: operand + result bytes (fusion = one
                   kernel: its internal ops don't touch HBM), x trip counts
  * collectives  — operand bytes per collective kind, x trip counts

Trip counts are read from the loop-condition computation's integer constant
(scan-generated conds are `lt(i, N)`).  Transcendentals are not counted
(matmul-dominated workloads; documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_ZERO_COST = {"parameter", "get-tuple-element", "tuple", "constant", "bitcast",
              "after-all", "opt-barrier"}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _shape_elems_bytes(typestr: str) -> Tuple[List[Tuple[str, List[int]]], int]:
    shapes = []
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        dd = [int(x) for x in dims.split(",")] if dims else []
        shapes.append((dt, dd))
        n = 1
        for x in dd:
            n *= x
        total += n * _DTYPE_BYTES.get(dt, 0)
    return shapes, total


@dataclass
class Op:
    name: str
    typestr: str
    kind: str
    args: str          # text inside the call parens (may be truncated at ')')
    attrs: str         # text after the call parens
    result_bytes: int = 0
    result_dims: List[int] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: Dict[str, Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    root: str = ""

    def alias_root(self, nm: str) -> str:
        """Follow bitcast/copy/reshape/transpose/convert pass-through chains
        (convert changes dtype, not which window of the buffer is touched)."""
        seen = set()
        while nm in self.ops and nm not in seen:
            seen.add(nm)
            op = self.ops[nm]
            if op.kind not in ("bitcast", "copy", "reshape", "transpose",
                               "convert"):
                break
            ins = _NAME_RE.findall(op.args)
            if len(ins) != 1:
                break
            nm = ins[0]
        return nm


def _split_call(rest: str) -> Tuple[str, str]:
    """rest = everything after 'opkind(' ; split into (args, attrs)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
            continue
        if line.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, typestr, kind, rest = m.groups()
        args, attrs = _split_call(rest)
        shapes, rbytes = _shape_elems_bytes(typestr)
        dims = shapes[0][1] if len(shapes) == 1 else []
        cur.ops[name] = Op(name, typestr, kind, args, attrs, rbytes, dims)
        cur.order.append(name)
        if line.lstrip().startswith("ROOT"):
            cur.root = name
    return comps


def _dims_from_attr(attrs: str, key: str) -> List[int]:
    m = re.search(rf"{key}=\{{([0-9,]*)\}}", attrs)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _dot_flops(op: Op, comp: Computation) -> float:
    ops_in = _NAME_RE.findall(op.args)
    if not ops_in:
        return 0.0
    lhs = comp.ops.get(ops_in[0])
    if lhs is None:
        return 0.0
    cdims = _dims_from_attr(op.attrs, "lhs_contracting_dims")
    csize = 1
    for d in cdims:
        if d < len(lhs.result_dims):
            csize *= lhs.result_dims[d]
    rsize = 1
    for d in op.result_dims:
        rsize *= d
    return 2.0 * rsize * csize


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops.values():
        if op.kind == "constant":
            m = re.match(r"^\s*([0-9]+)\s*$", op.args)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _callees(op: Op) -> List[Tuple[str, float]]:
    """(computation_name, multiplier) pairs invoked by this op."""
    out = []
    for key in ("calls", "to_apply", "branch_computations"):
        m = re.search(rf"{key}=\{{?%?([\w.\-]+(?:, ?%?[\w.\-]+)*)\}}?", op.attrs)
        if m:
            for nm in re.split(r",\s*", m.group(1)):
                out.append((nm.lstrip("%"), 1.0))
    return out


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=lambda: {
        k: {"count": 0.0, "operand_bytes": 0.0} for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in COLLECTIVES:
            self.coll[k]["count"] += other.coll[k]["count"] * mult
            self.coll[k]["operand_bytes"] += other.coll[k]["operand_bytes"] * mult


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}
        self._touch_memo: Dict[str, List[int]] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line)
                if m:
                    entry = m.group(1)
                break
        if entry is None:  # fall back: computation named main-ish
            entry = max(self.comps, key=lambda n: len(self.comps[n].ops))
        self.entry = entry

    def _operand_bytes(self, op: Op, comp: Computation,
                       skip=frozenset()) -> int:
        total = 0
        for nm in _NAME_RE.findall(op.args):
            if nm in skip:
                continue
            o = comp.ops.get(nm)
            if o is not None:
                total += o.result_bytes
        return total

    # ---- slice-aware operand accounting -------------------------------
    # dynamic-slice/gather touch only their RESULT-sized window of the
    # operand; dynamic-update-slice touches ~2x the update tensor.  Without
    # this, a scan slicing a (S, ...) xs tensor is charged the whole tensor
    # per trip (observed 100x inflation on the sLSTM cells).
    def _param_touch(self, comp_name: str) -> List[int]:
        """Per-parameter touched bytes inside a fusion computation, or -1
        for 'full operand'."""
        if comp_name in self._touch_memo:
            return self._touch_memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return []
        params: Dict[str, int] = {}   # param op name -> index
        for opname in comp.order:
            op = comp.ops[opname]
            if op.kind == "parameter":
                m = re.match(r"^\s*([0-9]+)", op.args)
                if m:
                    params[opname] = int(m.group(1))
        n = (max(params.values()) + 1) if params else 0
        touched = [0] * n
        full = [False] * n
        passthrough = ("bitcast", "copy", "reshape", "transpose")
        for opname in comp.order:
            op = comp.ops[opname]
            if op.kind == "parameter" or op.kind in passthrough:
                continue          # aliases analysed at their consumers
            raw = _NAME_RE.findall(op.args)
            roots = [comp.alias_root(nm) for nm in raw]
            for pos, r_nm in enumerate(roots):
                if r_nm not in params:
                    continue
                i = params[r_nm]
                if op.kind in ("dynamic-slice", "gather"):
                    touched[i] += op.result_bytes if pos == 0 else 0
                elif op.kind == "dynamic-update-slice":
                    if pos == 0 and len(raw) > 1:
                        upd = comp.ops.get(raw[1])
                        touched[i] += upd.result_bytes if upd else 0
                    else:          # the param IS the update (or index)
                        touched[i] += comp.ops[r_nm].result_bytes
                else:
                    full[i] = True
        out = [-1 if full[i] else touched[i] for i in range(n)]
        self._touch_memo[comp_name] = out
        return out

    def _dus_root_update_bytes(self, callee: str) -> int:
        """If the callee's ROOT is (an alias of) a dynamic-update-slice,
        the fusion writes only the update window, not the whole buffer.
        Returns the update size, or -1 if not a DUS-rooted fusion."""
        comp = self.comps.get(callee)
        if comp is None or not comp.root:
            return -1
        root = comp.alias_root(comp.root)
        op = comp.ops.get(root)
        if op is None or op.kind != "dynamic-update-slice":
            return -1
        ins = _NAME_RE.findall(op.args)
        if len(ins) > 1:
            upd = comp.ops.get(comp.alias_root(ins[1])) or comp.ops.get(ins[1])
            if upd is not None and upd.result_bytes:
                return upd.result_bytes
            # update produced inline (e.g. iota/compute); fall back to the
            # DUS result's smallest operand estimate: use op result / 64
            return max(1, op.result_bytes // 64)
        return -1

    def _call_boundary_bytes(self, op: Op, comp: Computation, callee: str,
                             skip=frozenset()) -> int:
        """Fusion/call boundary traffic with slice-aware parameter reads."""
        names = _NAME_RE.findall(op.args)
        touch = self._param_touch(callee)
        if op.name in skip:
            total = 0
        else:
            dus_upd = self._dus_root_update_bytes(callee)
            total = dus_upd if dus_upd >= 0 else op.result_bytes
        for i, nm in enumerate(names):
            if nm in skip:
                continue
            o = comp.ops.get(nm)
            if o is None:
                continue
            if i < len(touch) and touch[i] >= 0:
                total += min(touch[i], o.result_bytes)
            else:
                total += o.result_bytes
        return total

    # TPU producer-consumer fusion approximation: a fusible op whose result
    # has exactly ONE use, by another fusible op, stays on-chip — neither
    # its write nor the consumer's read hits HBM.  Without this, every CPU
    # fusion boundary (e.g. the f32 norm chains) is charged, inflating the
    # memory term ~2-3x vs what the TPU backend would emit.
    _FUSIBLE = {"fusion", "convert", "broadcast", "transpose", "reshape",
                "copy", "add", "multiply", "subtract", "divide", "tanh",
                "exponential", "negate", "maximum", "minimum", "compare",
                "select", "iota", "slice", "concatenate", "pad", "reduce"}

    def _use_counts(self, comp: Computation) -> Dict[str, int]:
        uses: Dict[str, int] = {}
        for opname in comp.order:
            op = comp.ops[opname]
            for nm in _NAME_RE.findall(op.args):
                if nm in comp.ops:
                    uses[nm] = uses.get(nm, 0) + 1
            # operands referenced in attrs (while init etc.) count too
            for nm in _NAME_RE.findall(op.attrs):
                if nm in comp.ops:
                    uses[nm] = uses.get(nm, 0) + 1
        return uses

    def _chain_maps(self, comp: Computation):
        """(skip_write, skip_read_edges): single-use fusible->fusible edges."""
        uses = self._use_counts(comp)
        consumers: Dict[str, List[str]] = {}
        for opname in comp.order:
            op = comp.ops[opname]
            for nm in _NAME_RE.findall(op.args):
                if nm in comp.ops:
                    consumers.setdefault(nm, []).append(opname)
        skip = set()
        for opname in comp.order:
            op = comp.ops[opname]
            if op.kind not in self._FUSIBLE:
                continue
            if uses.get(opname, 0) != 1:
                continue
            cons = consumers.get(opname, [])
            if len(cons) == 1 and comp.ops[cons[0]].kind in self._FUSIBLE:
                skip.add(opname)        # stays on-chip
        return skip

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # break cycles defensively
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        fused_away = self._chain_maps(comp)
        cost = Cost()
        for opname in comp.order:
            op = comp.ops[opname]
            kind = op.kind
            if kind in _ZERO_COST:
                continue
            if kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(self.comps[cond]) if cond in self.comps else 1
                if body in self.comps:
                    cost.add(self.comp_cost(body), trips)
                if cond in self.comps:
                    cost.add(self.comp_cost(cond), trips)
                continue
            if kind == "dot":
                cost.flops += _dot_flops(op, comp)
                cost.hbm_bytes += self._operand_bytes(op, comp, fused_away) \
                    + op.result_bytes
                continue
            if kind in COLLECTIVES or (kind.endswith("-start") and
                                       kind[:-6] in COLLECTIVES):
                base = kind[:-6] if kind.endswith("-start") else kind
                cost.coll[base]["count"] += 1
                cost.coll[base]["operand_bytes"] += self._operand_bytes(op, comp)
                cost.hbm_bytes += self._operand_bytes(op, comp) + op.result_bytes
                continue
            if kind.endswith("-done"):
                continue
            if kind in ("dynamic-slice", "gather"):
                ops_in = _NAME_RE.findall(op.args)
                idx_bytes = sum(comp.ops[nm].result_bytes
                                for nm in ops_in[1:] if nm in comp.ops)
                cost.hbm_bytes += 2 * op.result_bytes + idx_bytes
                continue
            if kind == "dynamic-update-slice":
                ops_in = _NAME_RE.findall(op.args)
                upd = comp.ops.get(ops_in[1]) if len(ops_in) > 1 else None
                ub = upd.result_bytes if upd else op.result_bytes
                cost.hbm_bytes += 2 * ub    # read update + write window
                continue
            callees = _callees(op)
            if kind in ("fusion", "call", "conditional", "async-start"):
                for cn, mult in callees:
                    sub = self.comp_cost(cn)
                    cost.flops += sub.flops * mult
                    for k in COLLECTIVES:
                        cost.coll[k]["count"] += sub.coll[k]["count"] * mult
                        cost.coll[k]["operand_bytes"] += \
                            sub.coll[k]["operand_bytes"] * mult
                # fusion = one kernel: slice-aware boundary traffic only
                if kind == "fusion" and callees:
                    cost.hbm_bytes += self._call_boundary_bytes(
                        op, comp, callees[0][0], fused_away)
                else:
                    cost.hbm_bytes += self._operand_bytes(op, comp, fused_away) \
                        + op.result_bytes
                continue
            if kind in ("map", "scatter", "select-and-scatter", "sort"):
                # tiny scalar to_apply bodies: boundary bytes only
                cost.hbm_bytes += self._operand_bytes(op, comp, fused_away) \
                    + op.result_bytes
                continue
            # plain top-level op (copy, broadcast, transpose, reduce, ...)
            cost.hbm_bytes += self._operand_bytes(op, comp, fused_away) + \
                (0 if opname in fused_away else op.result_bytes)
        self._memo[name] = cost
        return cost

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze(text: str) -> Dict:
    cost = HloCostModel(text).entry_cost()
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collectives": cost.coll,
        "collective_bytes": sum(v["operand_bytes"] for v in cost.coll.values()),
    }
