"""Production training launcher.

On a real TPU pod this process runs per-host under `jax.distributed`; here it
drives the same code path on however many (fake or real) devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 20 --dp 1 --tp 1
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--batch", type=int, default=0, help="override batch")
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--elastic-target", type=float, default=0.0,
                    help=">0: run under the Enel elastic controller")
    args = ap.parse_args()

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_shape, smoke_config
    from repro.data.pipeline import DataConfig, global_batch
    from repro.launch.mesh import dp_size as mesh_dp_size, make_mesh
    from repro.launch.shardings import (batch_shardings, logical_rules,
                                        state_shardings)
    from repro.models.sharding import use_rules
    from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                        save_checkpoint)
    from repro.train.optimizer import AdamWConfig
    from repro.train.train import init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    shape = get_shape(args.shape)
    if args.seq or args.batch:
        shape = dataclasses.replace(
            shape, seq_len=args.seq or shape.seq_len,
            global_batch=args.batch or shape.global_batch)

    if args.elastic_target > 0:
        from repro.train.elastic import ElasticConfig, ElasticTrainer
        ecfg = ElasticConfig(target_runtime=args.elastic_target,
                             n_components=max(1, args.steps // 4),
                             steps_per_component=4,
                             dp_choices=tuple(sorted({1, 2, args.dp})),
                             ckpt_dir=args.ckpt)
        res = ElasticTrainer(cfg, shape, ecfg).run()
        print(f"[elastic] {res}")
        return

    mesh = make_mesh(args.dp, args.tp, args.pods)
    rules = logical_rules(cfg, mesh, shape)
    opt = AdamWConfig(total_steps=args.steps)
    with mesh, use_rules(mesh, rules):
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        ssh = state_shardings(cfg, mesh, state)
        state = jax.device_put(state, ssh)
        start = 0
        if args.resume and latest_step(args.ckpt) is not None:
            host = jax.tree_util.tree_map(np.asarray, state)
            state, start, _ = restore_checkpoint(args.ckpt, host,
                                                 shardings=ssh)
            print(f"[train] resumed at step {start}")
        step_fn = jax.jit(make_train_step(cfg, opt),
                          in_shardings=(ssh, None), out_shardings=None,
                          donate_argnums=0)
        dcfg = DataConfig()
        t0 = time.time()
        for i in range(start, args.steps):
            nb = global_batch(dcfg, cfg, shape, i,
                              dp_size=max(1, shape.global_batch //
                                          max(args.batch or 4, 1)),
                              seq_len=min(shape.seq_len, 256))
            batch = {k: jnp.asarray(v) for k, v in nb.items()}
            state, metrics = step_fn(state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"[train] step {i} loss={float(metrics['loss']):.4f}")
            if (i + 1) % args.ckpt_every == 0:
                host = jax.tree_util.tree_map(np.asarray, state)
                save_checkpoint(args.ckpt, i + 1, host)
        print(f"[train] {args.steps - start} steps in {time.time()-t0:.1f}s "
              f"on mesh {dict(mesh.shape)}")


if __name__ == "__main__":
    main()
