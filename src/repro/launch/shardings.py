"""Sharding rules: param-path -> PartitionSpec, plus logical activation rules.

Scheme (DESIGN.md §4): DP over ('pod','data'); FSDP over 'data'; TP/EP over
'model'.  Divisibility is checked per-dim — an axis that does not divide the
dim is dropped (e.g. head-replicated attention for arctic/gemma2/qwen2.5).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import dp_axes, dp_size
from repro.models import init_cache
from repro.models.attention import padded_heads

Axis = Optional[object]


def _fits(mesh: Mesh, axis, dim: int) -> bool:
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def _guard(mesh: Mesh, spec: Tuple[Axis, ...], shape) -> P:
    return P(*[a if _fits(mesh, a, d) else None for a, d in zip(spec, shape)])


# ------------------------------------------------------------- param rules
# (parent, name) -> base spec for the *unstacked* array; stacked group params
# get leading None dims prepended automatically.
_IN = ("data", "model")     # (d_in, parallel_out)
_OUT = ("model", "data")    # (parallel_in, d_out)
_RULES: Dict[Tuple[str, str], Tuple[Axis, ...]] = {
    ("", "embed"): ("model", "data"),      # vocab x d, FSDP'd on d
    ("", "unembed"): ("model", "data"),
    ("attn", "wq"): _IN, ("attn", "wk"): _IN, ("attn", "wv"): _IN,
    ("attn", "wo"): _OUT,
    ("attn", "bq"): (None,), ("attn", "bk"): (None,), ("attn", "bv"): (None,),
    ("attn", "q_norm"): (None,), ("attn", "k_norm"): (None,),
    ("cross", "wq"): _IN, ("cross", "wk"): _IN, ("cross", "wv"): _IN,
    ("cross", "wo"): _OUT,
    ("cross", "q_norm"): (None,), ("cross", "k_norm"): (None,),
    ("ffn", "w_gate"): _IN, ("ffn", "w_up"): _IN, ("ffn", "w_down"): _OUT,
    ("moe", "router"): ("data", None),
    ("moe", "w_gate"): ("model", "data", None),
    ("moe", "w_up"): ("model", "data", None),
    ("moe", "w_down"): ("model", None, "data"),
    ("mamba", "in_proj"): _IN, ("mamba", "out_proj"): _OUT,
    ("mamba", "conv_w"): (None, "model"), ("mamba", "conv_b"): ("model",),
    ("mamba", "x_proj"): ("model", None), ("mamba", "dt_proj"): (None, "model"),
    ("mamba", "dt_bias"): ("model",), ("mamba", "A_log"): ("model", None),
    ("mamba", "D"): ("model",),
    ("mixer", "wq"): _IN, ("mixer", "wk"): _IN, ("mixer", "wv"): _IN,
    ("mixer", "w_gate"): _IN, ("mixer", "w_out"): _OUT,
    ("mixer", "w_i"): ("data", None), ("mixer", "w_f"): ("data", None),
    ("mixer", "b_i"): (None,), ("mixer", "b_f"): (None,),
    ("mixer", "w"): ("data", None), ("mixer", "r"): (None, None, None, None),
    ("mixer", "b"): (None,),
}


def _path_str(path) -> Tuple[str, str]:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
        else:
            keys.append(str(k))
    name = keys[-1]
    parent = ""
    for cand in reversed(keys[:-1]):
        if cand in ("attn", "cross", "ffn", "moe", "mamba", "mixer"):
            parent = cand
            break
    return parent, name


def param_spec(mesh: Mesh, path, leaf) -> P:
    parent, name = _path_str(path)
    base = _RULES.get((parent, name))
    if base is None:
        if name in ("ln1", "ln2", "ln_cross", "final_norm", "q_norm", "k_norm"):
            base = (None,) * leaf.ndim
            return P(*base)
        base = (None,) * leaf.ndim            # default: replicate
    pad = leaf.ndim - len(base)
    assert pad >= 0, (parent, name, leaf.ndim, base)
    spec = (None,) * pad + tuple(base)
    return _guard(mesh, spec, leaf.shape)


def tree_shardings(mesh: Mesh, tree):
    """NamedSharding tree for params / opt-state-like trees."""
    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # opt-state mu/nu paths look like mu/<param path>: strip the prefix
        return NamedSharding(mesh, param_spec(mesh, path, leaf))
    return jax.tree_util.tree_map_with_path(one, tree)


# ---------------------------------------------------------- logical rules
def logical_rules(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> Dict:
    tp = mesh.shape["model"]
    b = shape.global_batch
    dpx = dp_axes(mesh)
    dp: Axis = dpx if (b % dp_size(mesh) == 0) else (
        ("data",) if b % mesh.shape["data"] == 0 else None)
    kv_ok = cfg.n_kv_heads % tp == 0
    heads_ok = padded_heads(cfg) % tp == 0
    if b == 1:
        cache_seq: Axis = ("data", "model") if not kv_ok else ("data",)
    else:
        cache_seq = "model" if not kv_ok else None
    sp = "model" if (cfg.seq_parallel_residual and shape.kind == "train"
                     and shape.seq_len % tp == 0) else None
    return {
        "dp": dp,
        "tp_heads": "model" if heads_ok else None,
        "tp_kv": "model" if kv_ok else None,
        # sequence-parallel attention when heads aren't TP-shardable
        "kv_seq": None if heads_ok else "model",
        "tp_ff": "model",
        "ep": "model" if (cfg.n_experts and cfg.n_experts % tp == 0) else None,
        "cache_seq": cache_seq,
        "sp": sp,
        "vocab": "model",
    }


# ---------------------------------------------------------- batch / cache
def batch_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    rules = logical_rules(cfg, mesh, shape)
    dp = rules["dp"]

    def ns(*axes):
        return NamedSharding(mesh, P(*axes))

    if shape.kind in ("train", "prefill"):
        out = {"tokens": ns(dp, None)}
        if shape.kind == "train":
            out["targets"] = ns(dp, None)
        if cfg.family == "audio":
            out["frames"] = ns(dp, None, None)
        if cfg.family == "vlm":
            out["patches"] = ns(dp, None, None)
        return out
    return {"token": ns(dp, None), "pos": ns()}


def cache_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    """Structure mirrors models.transformer.init_cache."""
    rules = logical_rules(cfg, mesh, shape)
    dp, cseq, kv = rules["dp"], rules["cache_seq"], rules["tp_kv"]
    tpff = rules["tp_ff"]

    def ns(*axes):
        return NamedSharding(mesh, P(*axes))

    def entry(kind: str, stacked: bool):
        pre = (None,) if stacked else ()

        def mk(*axes):
            return NamedSharding(mesh, P(*(pre + axes)))

        if kind in ("attn", "attn_local"):
            e = {"k": mk(dp, cseq, kv, None), "v": mk(dp, cseq, kv, None)}
            if cfg.family == "audio":
                e["ck"] = mk(dp, None, kv, None)
                e["cv"] = mk(dp, None, kv, None)
            return e
        if kind == "mamba":
            return {"h": mk(dp, tpff, None), "conv": mk(dp, None, tpff)}
        if kind == "mlstm":
            return {"C": mk(dp, None, None, tpff), "n": mk(dp, None, None),
                    "m": mk(dp, None)}
        if kind == "slstm":
            return {k: mk(dp, None, None) for k in ("h", "c", "n", "m")}
        raise ValueError(kind)

    period = cfg.layer_period
    groups = {f"p{j}": entry(cfg.layer_kind(j), True) for j in range(period)}
    base = cfg.n_groups * period
    tail = [entry(cfg.layer_kind(base + t), False)
            for t in range(cfg.tail_layers)]
    return {"groups": groups, "tail": tail}


def state_shardings(cfg: ModelConfig, mesh: Mesh, state_struct):
    """Shardings for {"params": ..., "opt": {mu, nu, step}}."""
    params_sh = tree_shardings(mesh, state_struct["params"])
    return {
        "params": params_sh,
        "opt": {
            "mu": tree_shardings(mesh, state_struct["opt"]["mu"]),
            "nu": tree_shardings(mesh, state_struct["opt"]["nu"]),
            "step": NamedSharding(mesh, P()),
        },
    }


def scalar_shardings(mesh: Mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
