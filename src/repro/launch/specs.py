"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the batch pytree for the step function
selected by ``shape.kind``; decode shapes additionally need the cache struct
(``cache_specs``) and train shapes the state struct (``state_specs``).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import init_cache, init_model
from repro.train.optimizer import AdamWConfig, init_opt_state

I32 = jnp.int32
BF16 = jnp.bfloat16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def text_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        return shape.seq_len - cfg.n_patches
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Batch ShapeDtypeStructs for the lowered step function."""
    b = shape.global_batch
    if shape.kind in ("train", "prefill"):
        s = text_len(cfg, shape)
        batch = {"tokens": sds((b, s), I32)}
        if shape.kind == "train":
            batch["targets"] = sds((b, s), I32)
        if cfg.family == "audio":
            batch["frames"] = sds((b, cfg.enc_frames, cfg.d_model), BF16)
        if cfg.family == "vlm":
            batch["patches"] = sds((b, cfg.n_patches, cfg.d_model), BF16)
        return batch
    # decode: one new token against a seq_len cache
    return {"token": sds((b, 1), I32), "pos": sds((), I32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    b = shape.global_batch
    return jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len, BF16))


def state_specs(cfg: ModelConfig, opt: AdamWConfig) -> Dict:
    def build(key):
        params = init_model(key, cfg)
        return {"params": params, "opt": init_opt_state(params, cfg.opt_dtype)}
    return jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_specs(cfg: ModelConfig) -> Dict:
    return jax.eval_shape(lambda k: init_model(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def bytes_of(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))
