"""Recompute cost fields in dry-run artifacts from their saved HLO (no
recompilation) — used when the hlo_cost model improves.

    PYTHONPATH=src python -m repro.launch.reanalyze [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import zstandard

from repro.launch.hlo_analysis import dominant_term, roofline_terms
from repro.launch.hlo_cost import analyze


def reanalyze_file(json_path: Path) -> bool:
    hlo_path = json_path.with_suffix("").with_suffix(".hlo.zst") \
        if json_path.name.endswith(".json") else None
    hlo_path = json_path.parent / (json_path.stem + ".hlo.zst")
    if not hlo_path.exists():
        return False
    rec = json.loads(json_path.read_text())
    if rec.get("status") != "ok":
        return False
    text = zstandard.ZstdDecompressor().decompress(
        hlo_path.read_bytes()).decode()
    hc = analyze(text)
    rec["collectives"] = hc["collectives"]
    rec["collective_bytes_per_device"] = hc["collective_bytes"]
    rec["flops_per_device"] = hc["flops"]
    rec["bytes_per_device"] = hc["hbm_bytes"]
    terms = roofline_terms(hc["flops"], hc["hbm_bytes"],
                           hc["collective_bytes"])
    rec["roofline"] = terms
    rec["dominant"] = dominant_term(terms)
    mfd = rec.get("model_flops_per_device")
    rec["useful_flops_ratio"] = (mfd / hc["flops"]) if (mfd and hc["flops"]) \
        else None
    json_path.write_text(json.dumps(rec, indent=1))
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    n = 0
    for p in sorted(Path(args.dir).glob("*.json")):
        if reanalyze_file(p):
            n += 1
            rec = json.loads(p.read_text())
            t = rec["roofline"]
            print(f"[reanalyze] {p.stem}: compute={t['t_compute']:.4f} "
                  f"mem={t['t_memory']:.4f} coll={t['t_collective']:.4f} "
                  f"dominant={rec['dominant']}")
    print(f"[reanalyze] updated {n} artifacts")


if __name__ == "__main__":
    main()
