"""Serving launcher: batched request waves against a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--waves", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config, smoke_config
    from repro.models import init_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=args.max_len)
    rng = np.random.RandomState(0)
    for w in range(args.waves):
        reqs = [Request(prompt=rng.randint(2, cfg.raw_vocab_size,
                                           rng.randint(4, 24)),
                        max_new_tokens=8) for _ in range(args.batch)]
        stats = eng.serve_wave(reqs)
        print(f"[serve] wave {w}: {stats.tokens_out} tokens, "
              f"prefill {stats.prefill_s*1e3:.0f}ms, "
              f"decode {stats.decode_tok_s:.1f} tok/s")


if __name__ == "__main__":
    main()
