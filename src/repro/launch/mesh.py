"""Production mesh builders (functions, not constants: importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(dp: int, tp: int, pods: int = 1):
    """Elastic-runtime mesh: DP degree is a runtime parameter."""
    if pods > 1:
        return _mk((pods, dp, tp), ("pod", "data", "model"))
    return _mk((dp, tp), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
