import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks device count on first init.

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (SHAPES, get_config, get_shape, list_archs,  # noqa: E402
                           shape_applicable)
from repro.launch.hlo_analysis import (collective_stats, dominant_term,  # noqa: E402
                                       roofline_terms, total_collective_bytes)
from repro.launch.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.launch.mesh import dp_size, make_production_mesh  # noqa: E402
from repro.launch.shardings import (batch_shardings, cache_shardings,  # noqa: E402
                                    logical_rules, state_shardings,
                                    tree_shardings)
from repro.launch.specs import (cache_specs, input_specs, param_specs,  # noqa: E402
                                state_specs, bytes_of)
from repro.models import active_param_count, decode_step, prefill  # noqa: E402
from repro.models.sharding import use_rules  # noqa: E402
from repro.train.optimizer import AdamWConfig  # noqa: E402
from repro.train.train import make_train_step  # noqa: E402


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {"note": "memory_analysis unavailable"}
    if ma is None:
        return {"note": "memory_analysis returned None"}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "temp_size_in_bytes"):
        try:
            out[attr] = int(getattr(ma, attr))
        except Exception:
            pass
    if not out:
        out = {"repr": str(ma)}
    return out


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" not in k)}


def model_flops(cfg, shape) -> float:
    n = active_param_count(cfg)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch           # decode: one token per seq


def _parse_overrides(spec: str) -> dict:
    out = {}
    for kv in filter(None, (spec or "").split(",")):
        k, v = kv.split("=", 1)
        if v in ("true", "True"):
            out[k] = True
        elif v in ("false", "False"):
            out[k] = False
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt: AdamWConfig = AdamWConfig(), overrides: dict = None):
    """Build + lower + compile one (arch, shape, mesh) cell.

    Returns (lowered, compiled, meta-dict)."""
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = logical_rules(cfg, mesh, shape)
    n_dev = mesh.devices.size
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": int(n_dev), "rules": {k: str(v) for k, v in rules.items()}}

    with mesh, use_rules(mesh, rules):
        t0 = time.time()
        if shape.kind == "train":
            sstruct = state_specs(cfg, opt)
            ssh = state_shardings(cfg, mesh, sstruct)
            bsh = batch_shardings(cfg, mesh, shape)
            ga = max(1, min(cfg.grad_accum,
                            shape.global_batch // dp_size(mesh)))
            meta["grad_accum"] = ga
            step = make_train_step(cfg, opt, grad_accum=ga)
            jitted = jax.jit(step, in_shardings=(ssh, bsh),
                             out_shardings=(ssh, NamedSharding(mesh, P())),
                             donate_argnums=0)
            lowered = jitted.lower(sstruct, input_specs(cfg, shape))
        elif shape.kind == "prefill":
            pstruct = param_specs(cfg)
            psh = tree_shardings(mesh, pstruct)
            bsh = batch_shardings(cfg, mesh, shape)
            csh = cache_shardings(cfg, mesh, shape)
            dp = rules["dp"]

            def prefill_step(params, batch):
                logits, cache = prefill(params, cfg, batch,
                                        cache_len=shape.seq_len)
                return logits[:, -1], cache

            jitted = jax.jit(
                prefill_step, in_shardings=(psh, bsh),
                out_shardings=(NamedSharding(mesh, P(dp, "model")), csh))
            lowered = jitted.lower(pstruct, input_specs(cfg, shape))
        else:  # decode
            pstruct = param_specs(cfg)
            psh = tree_shardings(mesh, pstruct)
            bsh = batch_shardings(cfg, mesh, shape)
            csh = cache_shardings(cfg, mesh, shape)
            cstruct = cache_specs(cfg, shape)
            dp = rules["dp"]

            def serve_step(params, cache, token, pos):
                logits, new_cache = decode_step(params, cfg, cache, token, pos)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt[:, None], new_cache

            jitted = jax.jit(
                serve_step, in_shardings=(psh, csh, bsh["token"], bsh["pos"]),
                out_shardings=(NamedSharding(mesh, P(dp, None)), csh),
                donate_argnums=1)
            lowered = jitted.lower(pstruct, cstruct,
                                   input_specs(cfg, shape)["token"],
                                   input_specs(cfg, shape)["pos"])
        meta["lower_s"] = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        meta["compile_s"] = time.time() - t0
    return lowered, compiled, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: Path,
             verbose: bool = True, overrides: dict = None,
             tag_suffix: str = "") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    tag = f"{arch}--{shape_name}--{'pod2' if multi_pod else 'pod1'}{tag_suffix}"
    out_path = outdir / f"{tag}.json"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "status": "skipped",
               "reason": reason}
        out_path.write_text(json.dumps(rec, indent=1))
        if verbose:
            print(f"[dryrun] {tag}: SKIP ({reason})")
        return rec

    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod,
                                             overrides=overrides)
        meta["overrides"] = overrides or {}
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape_name, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
        out_path.write_text(json.dumps(rec, indent=1))
        if verbose:
            print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}")
        return rec

    mem = _mem_analysis_dict(compiled)
    cost = _cost_dict(compiled)
    text = compiled.as_text()
    try:  # persist compressed HLO so costs can be re-analysed w/o recompiling
        import zstandard
        (outdir / f"{tag}.hlo.zst").write_bytes(
            zstandard.ZstdCompressor(level=6).compress(text.encode()))
    except Exception:
        pass
    # primary cost model: trip-count-aware HLO analysis (hlo_cost.py);
    # XLA's cost_analysis counts while bodies once and is kept for reference.
    hc = hlo_analyze(text)
    stats = hc["collectives"]
    coll_bytes = hc["collective_bytes"]
    flops_dev = hc["flops"]
    bytes_dev = hc["hbm_bytes"]
    terms = roofline_terms(flops_dev, bytes_dev, coll_bytes)
    mf = model_flops(cfg, shape)
    n_dev = meta["n_devices"]
    rec = {
        **meta, "status": "ok",
        "cost_analysis_xla": cost,
        "memory_analysis": mem,
        "collectives": stats,
        "collective_bytes_per_device": coll_bytes,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops_dev if flops_dev else None,
        "roofline": terms,
        "dominant": dominant_term(terms),
        "hlo_bytes": len(text),
    }
    out_path.write_text(json.dumps(rec, indent=1))
    if verbose:
        print(f"[dryrun] {tag}: OK compute={terms['t_compute']:.4f}s "
              f"mem={terms['t_memory']:.4f}s coll={terms['t_collective']:.4f}s "
              f"dominant={rec['dominant']} "
              f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)} "
              f"(lower {meta['lower_s']:.0f}s compile {meta['compile_s']:.0f}s)")
        print(f"[dryrun] {tag}: memory_analysis={mem}")
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", default="",
                    help="cfg overrides, e.g. moe_group=256,grad_accum=8")
    ap.add_argument("--tag", default="", help="artifact tag suffix")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    overrides = _parse_overrides(args.override)

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}--{shape}--{'pod2' if mp else 'pod1'}{args.tag}"
                p = outdir / f"{tag}.json"
                if args.skip_existing and p.exists():
                    rec = json.loads(p.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[dryrun] {tag}: cached ({rec['status']})")
                        continue
                rec = run_cell(arch, shape, mp, outdir, overrides=overrides,
                               tag_suffix=args.tag)
                n_err += rec.get("status") == "error"
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
