"""Cross-context evaluation harness: scenario x job target-compliance grid
plus the paper's model-reuse claim as measurable transfer cells.

Two entry points, both emitting benchmark-JSON-ready rows
(``benchmarks/scenario_suite.py`` merges them into ``BENCH_decision.json``):

* :func:`run_scenario_campaign` — one disturbance scenario over a fleet of
  jobs driven through :class:`~repro.dataflow.fleet.FleetCampaign`
  (profiling -> adaptive runs, decisions cross-batched, simulation on the
  vectorized engine by default).  The ``multi_tenant`` scenario routes
  through :meth:`FleetCampaign.arrival_campaign` instead: Poisson arrivals
  into a bounded executor pool with capacity-capped picks.
* :func:`run_transfer_cells` — train the Enel model under execution context
  A (scenario, dataset size), then deploy it under context B WITHOUT a
  scratch retrain (only target calibration + the runner's normal online
  fine-tune cadence), and measure target compliance in the deploy context
  ("one model can be reused across different execution contexts", §I/§VI;
  evaluation style after C3O's cross-context runtime prediction).
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro import obs
from repro.dataflow.fleet import FleetCampaign
from repro.dataflow.runner import JobExperiment, RunStats
from repro.dataflow.workloads import SCALEOUT_RANGE
from repro.sim.chaos import make_dispatch_chaos, make_injector
from repro.sim.engine import BatchedClusterSim
from repro.sim.scenarios import make_scenario

DEFAULT_JOBS = ("lr", "mpc", "kmeans", "gbt")
DEFAULT_SCENARIOS = ("baseline", "node_failure", "stragglers",
                     "spot_preemption", "interference_burst",
                     "data_skew_drift")
CHAOS_SCENARIOS = ("chaos_observations", "chaos_model", "chaos_timeouts",
                   "chaos_crashes")
# (train_scenario, train_size) -> (deploy_scenario, deploy_size) per job
DEFAULT_TRANSFER_CELLS = (
    ("baseline", 1.0, "node_failure", 1.0, "kmeans"),
    ("baseline", 1.0, "interference_burst", 1.0, "gbt"),
    ("baseline", 1.0, "baseline", 1.6, "kmeans"),
    ("node_failure", 1.0, "stragglers", 1.25, "gbt"),
)


def _adaptive_rows(stats: Sequence[RunStats]) -> Dict:
    sel = [s for s in stats if s is not None and s.kind not in ("profiling",)]
    if not sel:
        return {"runs": 0}
    cvc = np.array([s.cvc for s in sel], float)
    cvs = np.array([s.violation / 60.0 for s in sel], float)
    return {"runs": len(sel),
            "compliance": float(1.0 - cvc.mean()),
            "cvs_mean_min": float(cvs.mean()),
            "rescales_mean": float(np.mean([s.n_rescales for s in sel])),
            "failures_total": int(sum(s.n_failures for s in sel)),
            "runtime_mean_s": float(np.mean([s.runtime for s in sel])),
            "target_s": float(sel[0].target)}


def run_scenario_campaign(scenario_name: str,
                          job_keys: Sequence[str] = DEFAULT_JOBS, *,
                          engine: str = "batched", seed: int = 0,
                          profile_runs: int = 3, adaptive_runs: int = 3,
                          candidate_stride: int = 2) -> List[Dict]:
    """Run one scenario over a job fleet; returns one row per job plus a
    scenario summary row (fleet decisions/sec, wall time)."""
    sc = make_scenario(scenario_name, seed=seed)
    # one shared vectorized engine for the whole fleet, handed to every
    # experiment up front (no throwaway per-experiment backends)
    shared = BatchedClusterSim() if engine == "batched" else None
    exps = [JobExperiment(k, seed=seed + i, scenario=sc,
                          candidate_stride=candidate_stride, engine=engine,
                          backend=shared)
            for i, k in enumerate(job_keys)]
    campaign = FleetCampaign(exps)
    campaign.profile(profile_runs)
    t0 = time.time()
    if sc.pool_size > 0:                       # multi-tenant capacity model
        stats, trace = campaign.arrival_campaign(
            pool_size=sc.pool_size, arrival_rate=sc.arrival_rate,
            inject_failures=sc.inject_failures, seed=seed)
        per_exp = [[st] for st in stats]
        extra = {"pool_size": sc.pool_size,
                 "max_pool_used": max((t.pool_used for t in trace),
                                      default=0),
                 "capped_decisions": sum(t.capped_decisions for t in trace),
                 "rounds": len(trace)}
    else:
        per_exp = [[] for _ in exps]
        for _ in range(adaptive_runs):
            for st, acc in zip(campaign.adaptive_round(
                    "enel", inject_failures=sc.inject_failures), per_exp):
                acc.append(st)
        extra = {}
    wall = time.time() - t0
    decisions = sum(st.decide_calls for acc in per_exp for st in acc
                    if st is not None)
    rows = []
    for exp, acc in zip(exps, per_exp):
        row = {"scenario": scenario_name, "job": exp.job_key,
               "engine": engine, "seed": seed}
        row.update(_adaptive_rows(acc))
        rows.append(row)
    rows.append({"scenario": scenario_name, "job": "__fleet__",
                 "engine": engine, "seed": seed, "fleet_size": len(exps),
                 "wall_s_adaptive": wall,
                 "decisions": decisions,
                 "decisions_per_s": decisions / max(wall, 1e-9), **extra})
    return rows


def _robustness_cols(stats: Sequence[RunStats]) -> Dict:
    """Fault-handling aggregates over one experiment's adaptive runs."""
    sel = [s for s in stats if s is not None and s.kind != "profiling"]
    decisions = sum(s.decide_calls for s in sel)
    bad = 0
    for s in sel:
        for z in (s.scaleouts or ()):
            zf = float(z)
            ok = np.isfinite(zf) and \
                SCALEOUT_RANGE[0] <= zf <= SCALEOUT_RANGE[1]
            bad += not ok
    fb = sum(s.fallback_decisions for s in sel)
    return {"decisions": decisions,
            "fallback_decisions": fb,
            "fallback_rate": fb / max(decisions, 1),
            "retries": sum(s.retries for s in sel),
            "breaker_trips": sum(s.breaker_trips for s in sel),
            "shed_requests": sum(s.shed_requests for s in sel),
            "nonfinite_decisions": int(bad)}


def run_chaos_campaign(scenario_name: str,
                       job_keys: Sequence[str] = DEFAULT_JOBS, *,
                       engine: str = "batched", seed: int = 0,
                       profile_runs: int = 3, adaptive_runs: int = 6,
                       candidate_stride: int = 2) -> List[Dict]:
    """One controller-chaos scenario over a job fleet: profile cleanly,
    then run the adaptive campaign with the scenario's fault plan attached
    to the control plane (observation poisoning + cache corruption + model
    poisoning per experiment, dispatch timeouts at the service, controller
    crashes recovered from checkpoints).  Returns one row per job plus a
    fleet summary row with injected-fault and recovery counters."""
    sc = make_scenario(scenario_name, seed=seed)
    spec = sc.chaos
    shared = BatchedClusterSim() if engine == "batched" else None
    exps = [JobExperiment(k, seed=seed + i, scenario=sc,
                          candidate_stride=candidate_stride, engine=engine,
                          backend=shared)
            for i, k in enumerate(job_keys)]
    campaign = FleetCampaign(exps)
    campaign.profile(profile_runs)
    # faults start AFTER profiling: the control plane degrades mid-flight,
    # it does not start broken
    for exp in exps:
        exp.chaos = make_injector(spec, exp.seed)
    campaign.service.fault_injector = make_dispatch_chaos(spec)
    t0 = time.time()
    restores = 0
    if spec.crash_rounds:
        all_stats, restores = campaign.adaptive_campaign_resilient(
            adaptive_runs, "enel", sc.inject_failures,
            crash_rounds=spec.crash_rounds, checkpoint_every=1)
    else:
        all_stats, _ = campaign.adaptive_campaign(
            adaptive_runs, "enel", sc.inject_failures)
    wall = time.time() - t0
    per_exp = [[run[i] for run in all_stats] for i in range(len(exps))]
    rows = []
    for exp, acc in zip(exps, per_exp):
        row = {"scenario": scenario_name, "chaos": spec.name,
               "job": exp.job_key, "engine": engine, "seed": seed}
        row.update(_adaptive_rows(acc))
        row.update(_robustness_cols(acc))
        if exp.chaos is not None:
            row.update(exp.chaos.snapshot())
        rows.append(row)
    svc = campaign.service
    fleet = {"scenario": scenario_name, "chaos": spec.name,
             "job": "__fleet__", "engine": engine, "seed": seed,
             "fleet_size": len(exps), "wall_s_adaptive": wall,
             "restores": restores,
             "quarantined_rows": sum(
                 exp.trainer.cache.quarantined for exp in exps
                 if exp.trainer.cache is not None),
             "poisoned_fits": sum(exp.trainer.poisoned_fits
                                  for exp in exps)}
    # service counters now live in the metrics registry; ``stats()`` is
    # the registry-backed successor of the old hand-built svc_* block
    fleet.update({f"svc_{k}": v for k, v in svc.stats().items()})
    if svc.fault_injector is not None:
        fleet["injected_timeouts"] = svc.fault_injector.timeouts
    if obs.enabled():
        fleet["controller_health"] = obs.registry().rows(prefix="enel_")
    rows.append(fleet)
    return rows


def chaos_trace_identity(job_keys: Sequence[str] = ("kmeans", "gbt"), *,
                         seed: int = 0, adaptive_runs: int = 4,
                         crash_rounds: Sequence[int] = (2, 5)) -> bool:
    """Acceptance check: a campaign killed at ``crash_rounds`` and restored
    from checkpoints must reproduce the uninterrupted campaign's decision
    trace exactly — WITH chaos active (model poisoning), since injectors
    are deterministic and checkpointed."""
    def build():
        sc = make_scenario("chaos_model", seed=seed)
        exps = [JobExperiment(k, seed=seed + 7 + i, scenario=sc,
                              candidate_stride=4, engine="batched")
                for i, k in enumerate(job_keys)]
        c = FleetCampaign(exps, engine="batched")
        c.profile(3)
        for exp in exps:
            exp.chaos = make_injector(sc.chaos, exp.seed)
        return c

    def trace(all_stats):
        return [(round(s.runtime, 4), round(s.violation, 4),
                 tuple(s.scaleouts), s.n_failures, s.n_rescales,
                 s.fallback_decisions)
                for run in all_stats for s in run]

    plain, _ = build().adaptive_campaign(adaptive_runs, "enel", True)
    crashed, restores = build().adaptive_campaign_resilient(
        adaptive_runs, "enel", True, crash_rounds=crash_rounds,
        checkpoint_every=1)
    return restores == len(tuple(crash_rounds)) and \
        trace(plain) == trace(crashed)


def run_transfer_cell(train_scenario: str, train_size: float,
                      deploy_scenario: str, deploy_size: float,
                      job_key: str, *, engine: str = "batched",
                      seed: int = 0, profile_runs: int = 3,
                      train_runs: int = 2, calibrate_runs: int = 3,
                      adaptive_runs: int = 3,
                      candidate_stride: int = 2) -> Dict:
    """Train under context A, deploy (reuse, no scratch retrain) under
    context B; returns one row with compliance in the deploy context."""
    sc_a = make_scenario(train_scenario, seed=seed)
    train = JobExperiment(job_key, seed=seed, scenario=sc_a,
                          size_scale=train_size, engine=engine,
                          candidate_stride=candidate_stride)
    train.profile(profile_runs)
    for _ in range(train_runs):
        train.adaptive_run("enel", inject_failures=sc_a.inject_failures)
    sc_b = make_scenario(deploy_scenario, seed=seed + 1)
    deploy = JobExperiment(job_key, seed=seed + 100, scenario=sc_b,
                           size_scale=deploy_size, engine=engine,
                           candidate_stride=candidate_stride,
                           share_models_from=train)
    # the transplanted model keeps its weights: only the runtime target is
    # calibrated in the new context (plus the normal online fine-tunes)
    deploy.calibrate_target(calibrate_runs)
    stats = [deploy.adaptive_run("enel",
                                 inject_failures=sc_b.inject_failures)
             for _ in range(adaptive_runs)]
    row = {"train_scenario": train_scenario, "train_size": train_size,
           "deploy_scenario": deploy_scenario, "deploy_size": deploy_size,
           "job": job_key, "engine": engine, "seed": seed}
    row.update(_adaptive_rows(stats))
    # prediction quality of the reused model in the NEW context
    pred = [(s.predicted, s.runtime) for s in stats
            if s.predicted is not None]
    if pred:
        row["pred_rel_err_mean"] = float(np.mean(
            [abs(p - r) / max(r, 1e-9) for p, r in pred]))
    return row


def run_transfer_cells(cells=DEFAULT_TRANSFER_CELLS, **kw) -> List[Dict]:
    return [run_transfer_cell(a, sa, b, sb, job, **kw)
            for a, sa, b, sb, job in cells]
