"""Vectorized multi-tenant cluster simulation: a whole fleet advances in
lockstep device steps instead of per-job Python event loops.

:class:`BatchedClusterSim` re-expresses the reference simulator
(``repro.dataflow.simulator.ClusterSim``) — Ernest-form stage runtimes,
AR(1) interference, rescale overheads, failure/restart dynamics and every
scenario disturbance — as one ``lax.scan`` over a ``(stages, jobs)`` batch.
Per fleet component-step (or per full run) it issues ONE jit dispatch for
all registered jobs.

Bit-parity contract (tested at batch=1 on all 4 paper jobs): the kernel
replays the float32 stage recipe documented in
``repro.dataflow.simulator`` op for op, reading the same precomputed
tables (``repro.sim.tables``) and the same seeded noise stream (a run's
``randn(T, N_NOISE)`` block equals the reference's per-stage sequential
draws).  The only numerical subtlety is FMA contraction: XLA:CPU contracts
``a*b + c`` into a fused multiply-add, which numpy never does, so every
product that feeds an add passes through :func:`_nc` — a value-preserving
``clip(x, -F32_MAX, F32_MAX)`` the compiler cannot fold away and therefore
cannot contract across.

Dispatch-cost layout: per-stage inputs ride in ONE packed float32 block
(noise | rt | sq | slow | cpu0 | shuffle0 | io0 | straggler | overhead, see
the ``_F*`` slices) plus one int block (z | inject; the start scale-out
only feeds host-side record fields) and a valid mask — a handful of
host->device conversions per dispatch instead of a dozen, with the
per-stage table rows pre-packed at build time so a step is a few memcpys.

The runner talks to either engine through the backend protocol at the
bottom (:class:`SimStepRequest` / :class:`NumpySimBackend` /
:class:`BatchedClusterSim`): the execution generator *yields* its pending
component step, so a fleet campaign batches every concurrent job's step
into one device dispatch while a single job just steps its private backend.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dataflow.simulator import (ClusterSim, ComponentRecord,
                                      StageRecord)
from repro.dataflow.workloads import JobSpec
from repro.sim.scenarios import BASELINE, Scenario
from repro.sim.tables import (F32, GLOBAL, MAX_FAIL_WINDOWS, N_NOISE, R_MAX,
                              T_STRAGGLER, W_MAX, FlatJobTables,
                              flat_job_tables, overhead_f32)

_F32_MAX = np.float32(3.4028235e38)

# packed float-block layout (last axis of the per-stage input block)
_F_NOISE = slice(0, 4)
_F_RT = slice(4, 41)
_F_SQ = slice(41, 78)
_F_SLOW = slice(78, 115)
_F_TAB = slice(4, 115)        # rt|sq|slow as stored in the packed tables
_F_CPU0, _F_SHUF0, _F_IO0, _F_STRAG, _F_OV = 115, 116, 117, 118, 119
_NF = 120


def _nc(x):
    """No-contract guard: identity for finite f32, but a min/max the
    compiler cannot remove — prevents FMA contraction of ``x`` into a
    following add (bit-parity with the numpy reference engine)."""
    return jnp.clip(x, -_F32_MAX, _F32_MAX)


def _gather_s(tab, idx):
    """(J, 37) table rows gathered at per-job scale-out idx -> (J,)."""
    return jnp.take_along_axis(tab, idx[:, None], axis=1)[:, 0]


# packed per-stage output layout (last axis): clock_before | runtime |
# metrics(5) | failed | fail_when(8) | fail_hit(8)
_O_CLK, _O_RT = 0, 1
_O_MET = slice(2, 7)
_O_FAILED = 7
_O_WHEN = slice(8, 8 + MAX_FAIL_WINDOWS)
_O_HIT = slice(8 + MAX_FAIL_WINDOWS, 8 + 2 * MAX_FAIL_WINDOWS)
_NO = 8 + 2 * MAX_FAIL_WINDOWS


def _make_body(kill_row, burst, preempt, iscale2, mem_tab, shuf_tab):
    """The shared float32 stage recipe as a scan body over a (jobs,) batch.

    carry: per-job (clock, interference) — padded/invalid stage slots leave
    the carry untouched (they consume no noise and no AR(1) step, exactly
    like the reference, which never executes them).  Both kernels (the
    per-component step and the whole-run dispatch) scan this SAME body, so
    their bit-parity with the reference engine is one property, not two.
    """
    def body(carry, x):
        clock, interf_prev = carry
        f, ii, val = x
        n0, n1, n2, n3 = (f[:, i] for i in range(4))
        z = ii[:, 0]
        w0f = jnp.floor(clock / 90.0)
        w0 = w0f.astype(jnp.int32)
        wi0 = jnp.clip(w0, 0, W_MAX - 1)
        burst_w = jnp.take_along_axis(burst, wi0[:, None], 1)[:, 0]
        innov = jnp.abs(n0) * (iscale2 * burst_w)
        interf = _nc(0.85 * interf_prev) + _nc(0.15 * innov)
        interf = jnp.clip(interf, 0.0, 0.45)
        loc = 1.0 + jnp.maximum(0.0, _nc(n1 * 0.04) + 0.02)
        loss = jnp.take_along_axis(preempt, wi0[:, None], 1)[:, 0]
        z_eff = jnp.maximum(z - loss, 1)
        base = _gather_s(f[:, _F_RT], z_eff)
        sqb = _gather_s(f[:, _F_SQ], z_eff)
        slow = _gather_s(f[:, _F_SLOW], z_eff)
        t = _nc(base * (1.0 + interf) * loc) + _nc(n2 * (0.15 * sqb))
        t = jnp.maximum(t, 0.2)
        t = _nc(t * f[:, _F_STRAG])
        t0 = t
        fail_ok = (ii[:, 1] > 0) & (z > 4) & val
        w_hi = jnp.minimum(jnp.floor((clock + t0) / 90.0).astype(jnp.int32),
                           w0 + MAX_FAIL_WINDOWS - 1)
        failed = jnp.zeros_like(w0)
        whens, hits = [], []
        for j in range(MAX_FAIL_WINDOWS):
            w = w0 + j
            when = jnp.take_along_axis(
                kill_row, jnp.clip(w, 0, W_MAX - 1)[:, None], 1)[:, 0]
            hit = fail_ok & (w <= w_hi) & (when >= clock) & \
                (when < clock + t0)
            frac = jnp.minimum(25.0, t) / jnp.maximum(t, 1e-6)
            t_new = _nc(t * (1.0 - frac)) + _nc((t * frac) * slow) + 18.0
            t = jnp.where(hit, t_new, t)
            failed = failed + hit.astype(jnp.int32)
            whens.append(when)
            hits.append(hit)
        runtime = t + f[:, _F_OV]
        mem = mem_tab[z_eff]
        gc = 0.04 + _nc(0.05 * mem)
        gc = gc + jnp.where(failed > 0, np.float32(0.05), np.float32(0.0))
        spill = jnp.maximum(0.0, mem - 1.4) * 0.3
        cpu = _nc(f[:, _F_CPU0] * (1.0 - interf)) + _nc(n3 * 0.02)
        cpu = jnp.clip(cpu, 0.0, 1.0)
        shuffle = f[:, _F_SHUF0] * shuf_tab[z_eff]
        io = f[:, _F_IO0] * jnp.where(failed > 0, np.float32(1.3),
                                      np.float32(1.0))
        clock_next = jnp.where(val, clock + runtime, clock)
        interf_next = jnp.where(val, interf, interf_prev)
        out = jnp.concatenate(
            [clock[:, None], runtime[:, None],
             jnp.stack([cpu, shuffle, io, gc, spill], axis=-1),
             failed[:, None].astype(jnp.float32),
             jnp.stack(whens, -1),
             jnp.stack(hits, -1).astype(jnp.float32)], axis=-1)
        return (clock_next, interf_next), out

    return body


@jax.jit
def _run_stages(state, fpack, ipack, valid, kill_row, burst, preempt,
                iscale2, mem_tab, shuf_tab):
    """Whole-batch scan with host-built per-stage inputs (run_full path)."""
    body = _make_body(kill_row, burst, preempt, iscale2, mem_tab, shuf_tab)
    carry, outs = jax.lax.scan(body, (state[:, 0], state[:, 1]),
                               (fpack, ipack, valid))
    return jnp.stack(carry, -1), outs


def _step_kernel_impl(run_block, ctrl, s_len, kill_row, burst, preempt,
                      iscale2, mem_tab, shuf_tab):
    """Per-component step against the device-resident run block.

    ``run_block``: (T, J, _NF) f32, uploaded ONCE per fleet run (noise,
    packed tables, stragglers).  ``ctrl``: (J, 8) f32 — the ONLY per-step
    upload: [clock, interf, a, z, inject, n_stages, overhead, cursor]
    (integer-valued columns are exact in f32).  The kernel slices each
    job's next ``n_stages`` rows at its cursor and runs the shared body.
    """
    t_max = run_block.shape[0]
    n_jobs = run_block.shape[1]
    cursor = ctrl[:, 7].astype(jnp.int32)
    steps = jnp.arange(s_len, dtype=jnp.int32)
    idx = jnp.clip(cursor[None, :] + steps[:, None], 0, t_max - 1)
    # whole-row gather over a flat (T*J, NF) view: XLA:CPU lowers this to
    # row copies, unlike an elementwise take_along_axis over (S, J, NF)
    flat = idx * n_jobs + jnp.arange(n_jobs, dtype=jnp.int32)[None, :]
    rows = jnp.take(run_block.reshape(t_max * n_jobs, -1),
                    flat.reshape(-1), axis=0).reshape(
                        s_len, n_jobs, run_block.shape[2])
    z = ctrl[:, 3].astype(jnp.int32)
    inject = ctrl[:, 4].astype(jnp.int32)
    n = ctrl[:, 5].astype(jnp.int32)
    first = steps[:, None] == 0
    ov = jnp.where(first, ctrl[None, :, 6], 0.0)
    rows = jnp.concatenate([rows[..., :_F_OV], ov[..., None]], axis=-1)
    # the body only consumes z and inject (the start scale-out a feeds the
    # host-side overhead/record fields, never the stage math)
    ipack = jnp.stack([jnp.broadcast_to(z[None, :], (s_len,) + z.shape),
                       jnp.broadcast_to(inject[None, :],
                                        (s_len,) + z.shape)], axis=-1)
    valid = steps[:, None] < n[None, :]
    body = _make_body(kill_row, burst, preempt, iscale2, mem_tab, shuf_tab)
    carry, outs = jax.lax.scan(body, (ctrl[:, 0], ctrl[:, 1]),
                               (rows, ipack, valid))
    return jnp.stack(carry, -1), outs


_step_kernel = jax.jit(_step_kernel_impl, static_argnums=(2,))


# ---------------------------------------------------------------- protocol
@dataclass
class SimStepRequest:
    """One job's pending component execution, yielded by the runner's
    execution generator and answered by a sim backend."""
    slot: int
    comp_idx: int
    start_scaleout: int
    end_scaleout: int
    clock: float
    inject_failures: bool


@dataclass
class SimStepResult:
    component: ComponentRecord
    failures: List[float]          # kill seconds observed in this component
    clock_end: float


class NumpySimBackend:
    """Per-job event-loop backend: each request runs through the reference
    :class:`ClusterSim` sequentially (the baseline the vectorized engine is
    benchmarked against)."""

    def __init__(self):
        self._slots: List[Tuple[ClusterSim, JobSpec]] = []

    def adopt(self, sim: ClusterSim, job: JobSpec) -> int:
        self._slots.append((sim, job))
        return len(self._slots) - 1

    def register(self, job: JobSpec, seed: int,
                 scenario: Optional[Scenario] = None,
                 interference_scale: float = 0.12) -> int:
        return self.adopt(ClusterSim(seed=seed, scenario=scenario,
                                     interference_scale=interference_scale),
                          job)

    def begin_run(self, slot: int) -> None:
        self._slots[slot][0].begin_run()

    def slot_state(self, slot: int) -> dict:
        """Mutable state of one registered sim, for campaign checkpoints."""
        return self._slots[slot][0].state_dict()

    def restore_slot(self, slot: int, state: dict) -> None:
        self._slots[slot][0].load_state_dict(state)

    def step(self, requests: Sequence[SimStepRequest]
             ) -> List[SimStepResult]:
        results = []
        for req in requests:
            sim, job = self._slots[req.slot]
            failures: List[float] = []
            comp = sim.run_component(
                job, req.comp_idx, clock=req.clock,
                start_scaleout=req.start_scaleout,
                end_scaleout=req.end_scaleout,
                inject_failures=req.inject_failures or
                sim.scenario.inject_failures, failures_log=failures)
            last = comp.stages[-1]
            results.append(SimStepResult(
                component=comp, failures=failures,
                clock_end=float(last.start + last.runtime)))
        return results


# ----------------------------------------------------------------- batched
class _Slot:
    def __init__(self, job: JobSpec, seed: int, scenario: Scenario,
                 interference_scale: float):
        self.job = job
        self.seed = seed
        self.scenario = scenario
        self.tables: FlatJobTables = flat_job_tables(job,
                                                     scenario.skew_growth)
        self.win = scenario.window_tables(seed)
        self.rng = np.random.RandomState(seed)
        self.iscale2 = F32(interference_scale * 2.0)
        self.clock = F32(0.0)
        self.interf = F32(0.0)
        self.run_idx = 0
        self.runs_started = 0
        self.cursor = 0               # stage cursor within the current run
        self.stage_idx = 0            # global stage counter (stragglers)
        self.noise = np.zeros((self.tables.total_stages, N_NOISE), F32)


class BatchedClusterSim:
    """Vectorized fleet engine; implements the same backend protocol as
    :class:`NumpySimBackend` but answers every concurrent request in one
    jit dispatch (and can run entire runs in one dispatch via
    :meth:`run_full`).

    State (clock, AR(1) interference, noise cursors, kill-table rows) is
    tracked per registered slot on the host and advanced only by the
    engine itself: the generator's ``req.clock`` must follow the engine's
    returned ``clock_end`` (the runner does) — steps replayed out of order
    would diverge from the reference stream.
    """

    def __init__(self):
        self._slots: List[_Slot] = []
        self._built = False
        self.dispatches = 0

    # ------------------------------------------------------------- registry
    def register(self, job: JobSpec, seed: int,
                 scenario: Optional[Scenario] = None,
                 interference_scale: float = 0.12) -> int:
        assert not self._built, "register before the first step/run_full"
        self._slots.append(_Slot(job, seed, scenario or BASELINE,
                                 interference_scale))
        return len(self._slots) - 1

    def _build(self):
        if self._built:
            return
        self._built = True
        self._J = len(self._slots)
        self._T = max(s.tables.total_stages for s in self._slots)
        self._S = max(int(s.tables.n_stages.max()) for s in self._slots)
        self._burst = jnp.asarray(np.stack([s.win["burst"]
                                            for s in self._slots]))
        self._preempt = jnp.asarray(np.stack([s.win["preempt"]
                                              for s in self._slots]))
        self._iscale2 = jnp.asarray(np.array([s.iscale2
                                              for s in self._slots]))
        self._mem_tab = jnp.asarray(GLOBAL["mem"])
        self._shuf_tab = jnp.asarray(GLOBAL["shuf"])
        self._kill_dev = None         # per-run upload, cached until begin_run
        # per-slot packed table block (T_j, 111): rt | sq | slow; plus the
        # scalar spec columns — copied into the run block by slice
        self._tabpack = []
        self._scalpack = []
        for s in self._slots:
            t = s.tables
            self._tabpack.append(np.concatenate(
                [t.rt, t.sq, t.slow], axis=1).astype(F32))
            self._scalpack.append(np.stack(
                [t.cpu0, t.shuffle0, t.io0], axis=1).astype(F32))
        # device-resident full-run input block for the stepped path: the
        # noise / tables / straggler columns of EVERY stage of the current
        # run, uploaded once per fleet run (dirty slots re-packed lazily at
        # the next step) — a step then ships only the (J, 8) control vector
        self._run_host = np.zeros((self._T, self._J, _NF), F32)
        self._run_host[:, :, _F_STRAG] = 1.0
        for j, s in enumerate(self._slots):
            tj = s.tables.total_stages
            self._run_host[:tj, j, _F_TAB] = self._tabpack[j]
            self._run_host[:tj, j, _F_CPU0:_F_IO0 + 1] = self._scalpack[j]
        self._run_dev = None
        self._dirty = set(range(self._J))

    # ------------------------------------------------------------ lifecycle
    def begin_run(self, slot: int) -> int:
        s = self._slots[slot]
        s.run_idx = s.runs_started
        s.runs_started += 1
        s.cursor = 0
        s.clock = F32(0.0)
        tj = s.tables.total_stages
        s.noise = s.rng.randn(tj * N_NOISE).astype(F32).reshape(tj, N_NOISE)
        self._kill_dev = None
        if self._built:
            self._dirty.add(slot)
        return s.run_idx

    # ----------------------------------------------------------- checkpoint
    def slot_state(self, slot: int) -> dict:
        """Mutable state of one slot, sufficient for a trace-identical
        resume: host RNG stream, clock/interference carry, stage cursors
        and the current run's pre-drawn noise block."""
        s = self._slots[slot]
        return {
            "rng": s.rng.get_state(),
            "clock": F32(s.clock),
            "interf": F32(s.interf),
            "run_idx": int(s.run_idx),
            "runs_started": int(s.runs_started),
            "cursor": int(s.cursor),
            "stage_idx": int(s.stage_idx),
            "noise": s.noise.copy(),
        }

    def restore_slot(self, slot: int, state: dict) -> None:
        s = self._slots[slot]
        s.rng.set_state(state["rng"])
        s.clock = F32(state["clock"])
        s.interf = F32(state["interf"])
        s.run_idx = int(state["run_idx"])
        s.runs_started = int(state["runs_started"])
        s.cursor = int(state["cursor"])
        s.stage_idx = int(state["stage_idx"])
        s.noise = state["noise"].copy()
        # invalidate the device-resident caches derived from slot state
        self._kill_dev = None
        if self._built:
            self._dirty.add(slot)

    def _kill_rows(self):
        if self._kill_dev is None:
            self._kill_dev = jnp.asarray(np.stack(
                [s.win["kill_time"][s.run_idx % R_MAX]
                 for s in self._slots]))
        return self._kill_dev

    def _strag_slice(self, slot: int, n: int) -> np.ndarray:
        s = self._slots[slot]
        # the run block holds the WHOLE run's stages, so the straggler
        # stream must be aligned to the run's first stage: normally the
        # pack happens right after begin_run (cursor 0), but a mid-run
        # checkpoint restore re-packs with the cursor already advanced
        base = s.stage_idx - s.cursor
        idx = (base + np.arange(n)) % T_STRAGGLER
        return s.win["straggler"][idx]

    def _run_block(self):
        """Device copy of the current run's stage inputs; slots whose run
        began since the last upload are re-packed, and the block is
        re-shipped once per fleet run (not per step)."""
        if self._dirty or self._run_dev is None:
            for j in self._dirty:
                s = self._slots[j]
                tj = s.tables.total_stages
                self._run_host[:tj, j, _F_NOISE] = s.noise
                self._run_host[:tj, j, _F_STRAG] = self._strag_slice(j, tj)
            self._dirty.clear()
            self._run_dev = jnp.asarray(self._run_host)
        return self._run_dev

    # ----------------------------------------------------------------- step
    def step(self, requests: Sequence[SimStepRequest]
             ) -> List[SimStepResult]:
        """Advance every requested job by one component in ONE dispatch;
        the only per-step host->device traffic is the (J, 8) control row."""
        self._build()
        ctrl = np.zeros((self._J, 8), F32)
        for j, s in enumerate(self._slots):
            ctrl[j, 0] = s.clock
            ctrl[j, 1] = s.interf
            ctrl[j, 7] = s.cursor
        spans: List[Tuple[int, int, int]] = []       # (slot, cursor, n)
        for req in requests:
            j = req.slot
            s = self._slots[j]
            c0 = int(s.tables.comp_start[req.comp_idx])
            n = int(s.tables.n_stages[req.comp_idx])
            assert s.cursor == c0, "steps must follow the run's stage order"
            a, z = int(req.start_scaleout), int(req.end_scaleout)
            ctrl[j, 2] = a
            ctrl[j, 3] = z
            ctrl[j, 4] = int(req.inject_failures or
                             s.scenario.inject_failures)
            ctrl[j, 5] = n
            ctrl[j, 6] = overhead_f32(a, z)
            spans.append((j, c0, n))
        state, outs = _step_kernel(
            self._run_block(), jnp.asarray(ctrl), self._S,
            self._kill_rows(), self._burst, self._preempt, self._iscale2,
            self._mem_tab, self._shuf_tab)
        self.dispatches += 1
        state = np.asarray(state)
        outs = np.asarray(outs)
        results = []
        for req, (j, c0, n) in zip(requests, spans):
            s = self._slots[j]
            s.clock = F32(state[j, 0])
            s.interf = F32(state[j, 1])
            s.cursor = c0 + n
            s.stage_idx += n
            comp, fails = self._records(req, s, outs, j, c0, n)
            results.append(SimStepResult(component=comp, failures=fails,
                                         clock_end=float(s.clock)))
        return results

    def _records(self, req, s: _Slot, outs: np.ndarray, j: int, c0: int,
                 n: int, row0: int = 0
                 ) -> Tuple[ComponentRecord, List[float]]:
        a, z = int(req.start_scaleout), int(req.end_scaleout)
        stages, fails = [], []
        for i in range(n):
            r = outs[row0 + i, j]
            sa = a if i == 0 else z
            ov = float(overhead_f32(a, z)) if i == 0 else 0.0
            nfail = int(r[_O_FAILED])
            stages.append(StageRecord(
                name=s.tables.names[c0 + i],
                start=r[_O_CLK],
                runtime=r[_O_RT],
                start_scaleout=float(sa), end_scaleout=float(z),
                time_fraction=1.0 if sa == z else 0.8,
                overhead=ov,
                metrics=r[_O_MET].copy(),
                failures=nfail))
            if nfail:
                fails.extend(float(w) for w, h in
                             zip(r[_O_WHEN], r[_O_HIT]) if h)
        return ComponentRecord(req.comp_idx, stages), fails

    # ------------------------------------------------------- fused campaign
    def campaign_run_blocks(self, n_runs: int
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Pre-draw the packed input blocks for ``n_runs`` consecutive fleet
        runs: ``(blocks (R, T, J, _NF), kill_rows (R, J, W_MAX))``.

        Consumes the slots' RNG streams and advances their run/stage
        counters exactly as ``n_runs`` stepped (or ``run_full``) runs would,
        so a fused campaign executed from these blocks sees the SAME noise /
        straggler / kill draws as the stepped path — and the backend's state
        afterwards is as if those runs had been started.  The per-step
        overhead column (``_F_OV``) is left 0: the fused kernel overwrites
        it from its on-device control row, like the stepped kernel does.
        """
        self._build()
        blocks = np.zeros((n_runs, self._T, self._J, _NF), F32)
        kills = np.zeros((n_runs, self._J, W_MAX), F32)
        for r in range(n_runs):
            for j in range(self._J):
                self.begin_run(j)
            for j, s in enumerate(self._slots):
                tj = s.tables.total_stages
                blocks[r, :tj, j, _F_NOISE] = s.noise
                blocks[r, :tj, j, _F_TAB] = self._tabpack[j]
                blocks[r, :tj, j, _F_CPU0:_F_IO0 + 1] = self._scalpack[j]
                blocks[r, :tj, j, _F_STRAG] = self._strag_slice(j, tj)
                blocks[r, tj:, j, _F_STRAG] = 1.0
                kills[r, j] = s.win["kill_time"][s.run_idx % R_MAX]
            for s in self._slots:       # advance cursors past the run
                s.cursor = s.tables.total_stages
                s.stage_idx += s.tables.total_stages
        self._kill_dev = None
        self._dirty.update(range(self._J))
        return blocks, kills

    def fused_sim_constants(self) -> dict:
        """The per-fleet constant arrays the stage body closes over — handed
        to the fused campaign kernel so it scans the SAME ``_make_body``."""
        self._build()
        return {"burst": self._burst, "preempt": self._preempt,
                "iscale2": self._iscale2, "mem_tab": self._mem_tab,
                "shuf_tab": self._shuf_tab, "t_max": self._T,
                "s_max": self._S}

    # ------------------------------------------------------------- full run
    def run_full(self, a_sched: np.ndarray, z_sched: np.ndarray,
                 inject_failures: bool = False
                 ) -> List[Tuple[List[ComponentRecord], List[float]]]:
        """One ENTIRE run of every registered job in a single dispatch.

        ``a_sched``/``z_sched``: (J, C_max) integer scale-out schedules
        (component c of job j starts at ``a_sched[j, c]`` and runs at
        ``z_sched[j, c]``); rescale decisions are fixed upfront, which is
        what profiling runs and scenario replays need.  Returns per job the
        component records and observed kill seconds.
        """
        self._build()
        J, T = self._J, self._T
        for j in range(J):
            self.begin_run(j)
        fbuf = np.zeros((T, J, _NF), F32)
        fbuf[:, :, _F_STRAG] = 1.0
        ibuf = np.zeros((T, J, 2), np.int32)  # z | inject (a is host-side)
        ibuf[:, :, 0] = 4
        vbuf = np.zeros((T, J), bool)
        for j, s in enumerate(self._slots):
            tj = s.tables.total_stages
            fbuf[:tj, j, _F_NOISE] = s.noise
            fbuf[:tj, j, _F_TAB] = self._tabpack[j]
            fbuf[:tj, j, _F_CPU0:_F_IO0 + 1] = self._scalpack[j]
            fbuf[:tj, j, _F_STRAG] = self._strag_slice(j, tj)
            comp = s.tables.comp_of
            first = s.tables.first_of_comp
            zs = z_sched[j, comp].astype(np.int32)
            as_ = np.where(first, a_sched[j, comp], zs).astype(np.int32)
            # overhead in the shared f32 op order (4 + 0.35*|z-a|, first
            # stage of a rescaling component only) — vectorized
            d = np.abs(zs - as_).astype(F32)
            fbuf[:tj, j, _F_OV] = np.where(
                first & (as_ != zs), F32(4.0) + F32(0.35) * d, F32(0.0))
            ibuf[:tj, j, 0] = zs
            ibuf[:, j, 1] = int(inject_failures or
                                s.scenario.inject_failures)
            vbuf[:tj, j] = True
        state0 = np.zeros((J, 2), F32)
        state0[:, 1] = [s.interf for s in self._slots]
        state, outs = _run_stages(
            jnp.asarray(state0), jnp.asarray(fbuf), jnp.asarray(ibuf),
            jnp.asarray(vbuf), self._kill_rows(), self._burst,
            self._preempt, self._iscale2, self._mem_tab, self._shuf_tab)
        self.dispatches += 1
        state = np.asarray(state)
        outs = np.asarray(outs)
        results = []
        for j, s in enumerate(self._slots):
            s.clock = F32(state[j, 0])
            s.interf = F32(state[j, 1])
            s.cursor = s.tables.total_stages
            s.stage_idx += s.tables.total_stages
            comps, fails = [], []
            for c in range(s.job.n_components):
                c0 = int(s.tables.comp_start[c])
                n = int(s.tables.n_stages[c])
                req = SimStepRequest(j, c, int(a_sched[j, c]),
                                     int(z_sched[j, c]), 0.0,
                                     bool(ibuf[0, j, 1]))
                comp, cf = self._records(req, s, outs, j, c0, n, row0=c0)
                comps.append(comp)
                fails.extend(cf)
            results.append((comps, fails))
        return results
