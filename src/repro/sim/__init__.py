"""Scenario engine: seeded disturbance scenarios + vectorized fleet simulation.

Import layering (to keep ``repro.dataflow.simulator`` importable on its own):
this package ``__init__`` only pulls in the leaf modules (``tables``,
``scenarios``); the vectorized engine lives in ``repro.sim.engine`` (it
imports the dataflow record types) and the evaluation harness in
``repro.sim.evaluate`` — import those explicitly.
"""
from repro.sim.scenarios import (BASELINE, SCENARIO_NAMES, Scenario,
                                 make_scenario)

__all__ = ["BASELINE", "SCENARIO_NAMES", "Scenario", "make_scenario"]
