"""Controller-side chaos injection: deterministic faults aimed at the
CONTROL PLANE (model, cache, decision service, campaign driver), not the
simulated cluster — the complement of the scenario disturbances in
``repro.sim.scenarios``, which attack the *environment*.

Four fault families, each exercising one robustness mechanism end-to-end:

=====================  =====================================================
``nan_graphs_every``   poisons observed component graphs (NaN metrics /
                       runtimes) before they enter ``graph_history`` —
                       caught by the :class:`~repro.core.graph.TrainingCache`
                       entry quarantine and the trainer's non-finite step
                       guard.
``cache_corrupt_every`` flips resident ring-buffer rows to NaN *in place*
                       (bit-rot / bad DMA analogue) — healed by
                       ``fit_resident``'s quarantine-and-retry sweep.
``nan_fit_every``      overwrites model parameters with NaN after a fit
                       (diverged/poisoned training analogue) — every
                       subsequent decision trips the on-device guardrail
                       and falls back to the bounded heuristic until the
                       next scratch retrain re-initializes the model.
``timeout_every``      raises :class:`~repro.core.service.DispatchTimeout`
                       inside the decision service's dispatch path (burst
                       of ``timeout_burst`` consecutive attempts) —
                       absorbed by retry/backoff; bursts longer than the
                       retry budget force fallback decisions and, repeated,
                       trip the circuit breaker.
``crash_rounds``       controller process death at campaign round
                       boundaries — recovered by checkpoint/restore
                       (``FleetCampaign.adaptive_campaign_resilient``).
=====================  =====================================================

Every fault is a pure function of ``(spec.seed, experiment seed, run/call
index)`` — no wall clock, no hidden RNG — so a chaos campaign replays
identically across processes AND across checkpoint/restore boundaries,
which is what lets the trace-identity acceptance check run *under* chaos.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs


@dataclass(frozen=True)
class ChaosSpec:
    """Frozen fault-injection plan (composes into :class:`Scenario`)."""
    name: str = "none"
    seed: int = 0
    nan_graphs_every: int = 0     # poison run observations every Nth run
    cache_corrupt_every: int = 0  # NaN a resident cache row every Nth run
    nan_fit_every: int = 0        # NaN model params after every Nth fit
    timeout_every: int = 0        # dispatch timeout every Nth service call
    timeout_burst: int = 1        # consecutive failing attempts per firing
    crash_rounds: Tuple[int, ...] = ()  # campaign rounds that "kill" the
    #                                     controller (checkpoint recovery)

    @property
    def active(self) -> bool:
        return bool(self.nan_graphs_every or self.cache_corrupt_every
                    or self.nan_fit_every or self.timeout_every
                    or self.crash_rounds)

    def key(self):
        return dataclasses.astuple(self)


CHAOS_NONE = ChaosSpec()


class ChaosInjector:
    """Per-experiment fault injector driven by ``JobExperiment`` hooks.

    ``poison_graphs`` fires between simulation and history/cache ingestion;
    ``after_fit`` fires right after the trainer's per-run fit.  Firing rule:
    run ``r`` fires for a family with period ``every`` iff
    ``r % every == (exp_seed ^ spec.seed) % every`` — experiments in one
    fleet fault on staggered runs instead of in lockstep.
    """

    def __init__(self, spec: ChaosSpec, exp_seed: int = 0):
        self.spec = spec
        self.exp_seed = int(exp_seed)
        self.graphs_poisoned = 0
        self.cache_rows_corrupted = 0
        self.fits_poisoned = 0

    def _fires(self, every: int, idx: int) -> bool:
        if every <= 0:
            return False
        return (idx % every) == ((self.exp_seed ^ self.spec.seed) % every)

    # ------------------------------------------------------- observation path
    def poison_graphs(self, graphs: Sequence, run_idx: int) -> List:
        """NaN the metrics and runtimes of one observed component graph
        (in-place on padded-array copies upstream of the cache)."""
        graphs = list(graphs)
        if not graphs or not self._fires(self.spec.nan_graphs_every, run_idx):
            return graphs
        import numpy as np
        victim = graphs[run_idx % len(graphs)]
        bad = dataclasses.replace(
            victim, metrics=victim.metrics.copy(),
            runtime=victim.runtime.copy())
        bad.metrics[bad.metrics_valid] = np.nan
        bad.runtime[bad.runtime_valid] = np.nan
        graphs[run_idx % len(graphs)] = bad
        self.graphs_poisoned += 1
        obs.emit("chaos", family="nan_graphs", spec=self.spec.name,
                 run=run_idx, victim=run_idx % len(graphs))
        return graphs

    # ---------------------------------------------------------- trainer path
    def after_fit(self, trainer, run_idx: int) -> None:
        """Post-fit faults: in-place cache corruption (self-healed by the
        next fit's quarantine sweep) and NaN parameter poisoning (forces
        guardrail fallbacks until the next scratch retrain)."""
        if self._fires(self.spec.cache_corrupt_every, run_idx):
            cache = getattr(trainer, "cache", None)
            if cache is not None and cache.count > 0:
                import jax.numpy as jnp
                slot = run_idx % cache.count
                cache.buffers = {
                    k: (v.at[slot].set(jnp.nan)
                        if jnp.issubdtype(v.dtype, jnp.floating) else v)
                    for k, v in cache.buffers.items()}
                self.cache_rows_corrupted += 1
                obs.emit("chaos", family="cache_corrupt",
                         spec=self.spec.name, run=run_idx, slot=slot)
        if self._fires(self.spec.nan_fit_every, run_idx):
            import jax
            import jax.numpy as jnp
            trainer.params = jax.tree_util.tree_map(
                lambda p: jnp.full_like(p, jnp.nan), trainer.params)
            self.fits_poisoned += 1
            obs.emit("chaos", family="nan_fit", spec=self.spec.name,
                     run=run_idx)

    # ------------------------------------------------------------ checkpoint
    def snapshot(self) -> Dict:
        return {"graphs_poisoned": self.graphs_poisoned,
                "cache_rows_corrupted": self.cache_rows_corrupted,
                "fits_poisoned": self.fits_poisoned}

    def restore(self, st: Dict) -> None:
        self.graphs_poisoned = int(st["graphs_poisoned"])
        self.cache_rows_corrupted = int(st["cache_rows_corrupted"])
        self.fits_poisoned = int(st["fits_poisoned"])


class DispatchChaos:
    """Service-level injector: plugs into ``DecisionService.fault_injector``
    (called once per dispatch *attempt*) and raises
    :class:`~repro.core.service.DispatchTimeout` on every
    ``timeout_every``-th dispatch, for ``timeout_burst`` consecutive
    attempts.  A burst longer than the retry budget turns the whole group
    into fallback decisions and feeds the circuit breaker.

    Counter-only state with ``snapshot``/``restore`` — the service folds it
    into its own checkpoint, so resumed campaigns replay the identical
    timeout pattern.
    """

    def __init__(self, spec: ChaosSpec):
        self.spec = spec
        self.dispatches = 0      # fault-free dispatch attempts seen
        self.timeouts = 0        # injected timeouts (lifetime)
        self._burst_left = 0     # remaining attempts of the current burst

    def __call__(self) -> None:
        if self.spec.timeout_every <= 0:
            return
        from repro.core.service import DispatchTimeout
        if self._burst_left > 0:
            self._burst_left -= 1
            self.timeouts += 1
            obs.emit("chaos", family="dispatch_timeout",
                     spec=self.spec.name, dispatch=self.dispatches,
                     burst_left=self._burst_left)
            raise DispatchTimeout(
                f"chaos[{self.spec.name}]: injected dispatch timeout "
                f"(burst, {self._burst_left} left)")
        self.dispatches += 1
        if self.dispatches % self.spec.timeout_every == 0:
            self._burst_left = max(int(self.spec.timeout_burst), 1) - 1
            self.timeouts += 1
            obs.emit("chaos", family="dispatch_timeout",
                     spec=self.spec.name, dispatch=self.dispatches,
                     burst_left=self._burst_left)
            raise DispatchTimeout(
                f"chaos[{self.spec.name}]: injected dispatch timeout")

    def snapshot(self) -> Dict:
        return {"dispatches": self.dispatches, "timeouts": self.timeouts,
                "burst_left": self._burst_left}

    def restore(self, st: Dict) -> None:
        self.dispatches = int(st["dispatches"])
        self.timeouts = int(st["timeouts"])
        self._burst_left = int(st["burst_left"])


def make_injector(spec: ChaosSpec, exp_seed: int = 0
                  ) -> Optional[ChaosInjector]:
    """Per-experiment injector, or None when the spec has no per-run
    faults (timeouts/crashes live at the service/campaign layer)."""
    if spec.nan_graphs_every or spec.cache_corrupt_every \
            or spec.nan_fit_every:
        return ChaosInjector(spec, exp_seed)
    return None


def make_dispatch_chaos(spec: ChaosSpec) -> Optional[DispatchChaos]:
    """Service-level timeout injector, or None when inactive."""
    return DispatchChaos(spec) if spec.timeout_every > 0 else None
