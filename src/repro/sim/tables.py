"""Precomputed float32 cost tables shared by BOTH simulator engines.

The scenario engine's bit-parity contract (numpy reference == vectorized jnp
engine at batch=1) requires that every stage-level quantity either

* is computed with IEEE-exact float32 ops (+, -, *, /, min, max, abs, floor,
  compare, select) in the SAME order on both sides, or
* comes out of a table precomputed ONCE host-side and merely *gathered* by
  both engines.

All transcendentals (log2 in the Ernest runtime form, sqrt of the base
runtime for the noise term, the 12/s memory-pressure curve) land in tables
indexed by the integer scale-out s in [0, 36], so neither engine ever
evaluates a libm function whose last ulp could differ between numpy and XLA.

Scale-outs are integers (paper §V-A: 4..36 Spark executors), which is what
makes the table trick exact rather than an approximation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Tuple

import numpy as np

if TYPE_CHECKING:              # type-only: keeps ``import repro.sim`` free
    # of the repro.dataflow package init (which imports repro.sim back)
    from repro.dataflow.workloads import JobSpec, StageSpec

F32 = np.float32
EXEC_MAX = 36                 # largest scale-out; tables are (EXEC_MAX+1,)
N_NOISE = 4                   # randn draws per stage: interf, loc, t, cpu
FAILURE_WINDOW = 90.0         # seconds per failure-injection window
W_MAX = 128                   # windows per run horizon (~3.2 h simulated)
R_MAX = 256                   # seeded kill-second rows before the table tiles
T_STRAGGLER = 8192            # straggler-multiplier stream length (tiles)
MAX_FAIL_WINDOWS = 8          # windows a single stage may span (<= 720 s)


def stage_tables(spec: StageSpec, growth: float = 1.0) -> Dict[str, np.ndarray]:
    """Per-stage lookup tables over integer scale-out s in [0, EXEC_MAX].

    ``growth`` scales the data-dependent (perfectly-parallel) term — the
    ``data_skew_drift`` scenario applies growth**component so later
    iterations process more data.
    """
    s = np.arange(EXEC_MAX + 1, dtype=np.float64)
    s[0] = 1.0                                     # s=0 never used; avoid inf
    rt = (spec.serial + growth * spec.parallel / s +
          spec.comm * np.log2(np.maximum(s, 2.0)) + spec.lin * s)
    rt = rt.astype(F32)
    rt[0] = rt[1]
    slow = rt[np.maximum(np.arange(EXEC_MAX + 1) - 1, 1)] / \
        np.maximum(rt, F32(1e-6))
    return {
        "rt": rt,
        "sq": np.sqrt(rt).astype(F32),
        "slow": slow.astype(F32),
        "cpu0": F32(spec.cpu),
        "shuffle0": F32(spec.shuffle),
        "io0": F32(spec.io),
    }


def global_tables() -> Dict[str, np.ndarray]:
    """Spec-independent per-scale-out tables (memory pressure, shuffle fan)."""
    s = np.arange(EXEC_MAX + 1, dtype=np.float64)
    s[0] = 1.0
    mem = np.clip(12.0 / s, 0.0, 2.5).astype(F32)
    shuf = (1.0 + 0.25 * np.log2(np.maximum(s, 2.0)) / 5.0).astype(F32)
    return {"mem": mem, "shuf": shuf}


GLOBAL = global_tables()


@dataclass
class FlatJobTables:
    """A job's full run flattened to its stage sequence (length T).

    The vectorized engine advances over this layout (components are
    contiguous stage ranges), and the numpy reference reads the same arrays
    per stage, so both engines see identical float32 table entries.
    """
    job: JobSpec
    names: list                      # stage name per flat slot
    comp_of: np.ndarray              # (T,) int32 component index
    first_of_comp: np.ndarray        # (T,) bool  first stage of its component
    comp_start: np.ndarray           # (C,) int32 offset of each component
    n_stages: np.ndarray             # (C,) int32 stages per component
    rt: np.ndarray                   # (T, 37) f32
    sq: np.ndarray                   # (T, 37) f32
    slow: np.ndarray                 # (T, 37) f32
    cpu0: np.ndarray                 # (T,) f32
    shuffle0: np.ndarray             # (T,) f32
    io0: np.ndarray                  # (T,) f32

    @property
    def total_stages(self) -> int:
        return len(self.names)


def flat_job_tables(job: JobSpec, skew_growth: float = 1.0) -> FlatJobTables:
    names, comp_of, first, rts, sqs, slows = [], [], [], [], [], []
    cpu0, shuffle0, io0, comp_start, n_stages = [], [], [], [], []
    for c in range(job.n_components):
        specs = job.stages(c)
        comp_start.append(len(names))
        n_stages.append(len(specs))
        growth = float(skew_growth) ** c
        for i, spec in enumerate(specs):
            tab = stage_tables(spec, growth)
            names.append(spec.name)
            comp_of.append(c)
            first.append(i == 0)
            rts.append(tab["rt"])
            sqs.append(tab["sq"])
            slows.append(tab["slow"])
            cpu0.append(tab["cpu0"])
            shuffle0.append(tab["shuffle0"])
            io0.append(tab["io0"])
    return FlatJobTables(
        job=job, names=names,
        comp_of=np.array(comp_of, np.int32),
        first_of_comp=np.array(first, bool),
        comp_start=np.array(comp_start, np.int32),
        n_stages=np.array(n_stages, np.int32),
        rt=np.stack(rts), sq=np.stack(sqs), slow=np.stack(slows),
        cpu0=np.array(cpu0, F32), shuffle0=np.array(shuffle0, F32),
        io0=np.array(io0, F32))


def overhead_f32(a: int, z: int) -> F32:
    """Rescale overhead in the engines' shared float32 op order."""
    if a == z:
        return F32(0.0)
    return F32(4.0) + F32(0.35) * F32(abs(int(z) - int(a)))


_WINDOW_CACHE: Dict[Tuple, Dict[str, np.ndarray]] = {}


def window_tables(scenario, sim_seed: int) -> Dict[str, np.ndarray]:
    """Seeded per-window / per-stage disturbance tables for one (scenario,
    simulator seed) pair; both engines index the SAME arrays.

    Draw order from one RandomState (fixed, so adding fields stays
    reproducible): kill fractions, burst regime, preemption losses,
    straggler multipliers.

    * ``kill_time[r, w]``: the one kill second of failure window ``w`` in
      run ``r`` (paper §V-B.4 — one executor kill at a random second per
      90 s window).  Per-window and per-run seeded: every stage that
      overlaps window ``w`` agrees on the same kill second, so exactly one
      kill fires per window (in whichever stage covers that second).
    * ``burst[w]``: interference-innovation multiplier (regime-switching
      AR(1): a seeded Markov chain enters/exits burst windows).
    * ``preempt[w]``: executors lost to spot preemption while window ``w``
      is active (correlated multi-executor loss).
    * ``straggler[t]``: per-stage runtime multiplier stream (1.0 or an
      exponential tail), indexed by the engine's global stage counter.
    """
    key = (scenario.key(), int(sim_seed))
    hit = _WINDOW_CACHE.get(key)
    if hit is not None:
        return hit
    mix = (int(sim_seed) * 2654435761 + scenario.seed * 97 + 0x9E3779B9) \
        % (2 ** 32)
    rng = np.random.RandomState(mix)
    frac = rng.uniform(0.0, 1.0, (R_MAX, W_MAX))
    kill_time = ((np.arange(W_MAX)[None, :] + frac) *
                 FAILURE_WINDOW).astype(F32)
    # burst regime: 2-state Markov chain over windows
    u = rng.uniform(0.0, 1.0, W_MAX)
    burst = np.ones(W_MAX, F32)
    state = False
    for w in range(W_MAX):
        state = (u[w] < scenario.burst_prob) if not state else \
            (u[w] >= scenario.burst_exit)
        if state:
            burst[w] = F32(scenario.burst_mult)
    # spot preemption: correlated loss of several executors in a window
    pu = rng.uniform(0.0, 1.0, W_MAX)
    psz = rng.randint(2, max(scenario.preempt_max, 2) + 1, W_MAX)
    preempt = np.where(pu < scenario.preempt_prob, psz, 0).astype(np.int32)
    # stragglers: occasional heavy-tailed per-stage slowdown
    su = rng.uniform(0.0, 1.0, T_STRAGGLER)
    tail = rng.exponential(max(scenario.straggler_scale, 1e-9), T_STRAGGLER)
    straggler = np.where(su < scenario.straggler_prob,
                         1.0 + tail, 1.0).astype(F32)
    out = {"kill_time": kill_time, "burst": burst, "preempt": preempt,
           "straggler": straggler}
    _WINDOW_CACHE[key] = out
    return out
