"""Named, seeded, composable disturbance scenarios (ROADMAP: "as many
scenarios as you can imagine"; paper §V-B.4 node failures + the C3O-style
cross-context axis).

A :class:`Scenario` is a frozen parameter record; all of its randomness is
materialized into seeded per-window / per-stage tables
(:func:`repro.sim.tables.window_tables`) that BOTH simulator engines index
identically — a scenario therefore produces the exact same disturbance
trajectory under the numpy reference and the vectorized engine.

Registry (each entry also composes with any other via dataclasses.replace):

=================== ========================================================
``baseline``        clean multi-tenant background (AR(1) interference only)
``node_failure``    paper-faithful: one kill per 90 s window while > 4
                    executors are allocated, per-window seeded second
``stragglers``      heavy-tailed per-stage slowdowns (p ~ straggler_prob)
``spot_preemption`` correlated loss of 2..preempt_max executors per window
``interference_burst`` regime-switching AR(1): seeded Markov bursts multiply
                    the interference innovation
``data_skew_drift`` per-iteration input growth: component k's parallel work
                    scales by skew_growth**k
``multi_tenant``    global executor pool + Poisson job arrivals (campaign
                    level: concurrent jobs contend, decisions are
                    capacity-capped — see FleetCampaign.arrival_campaign)
``chaos_*``         controller-side fault plans (repro.sim.chaos): poisoned
                    observations / cache bit-rot / NaN model params /
                    dispatch timeouts / controller crashes — attack the
                    CONTROL PLANE instead of the simulated cluster
=================== ========================================================
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict

from repro.sim import tables as T
from repro.sim.chaos import CHAOS_NONE, ChaosSpec


@dataclass(frozen=True)
class Scenario:
    name: str = "baseline"
    seed: int = 0
    inject_failures: bool = False      # node_failure injector always on
    straggler_prob: float = 0.0        # P(stage is a straggler)
    straggler_scale: float = 0.0       # exponential tail scale of slowdown
    burst_prob: float = 0.0            # P(enter burst) per window
    burst_exit: float = 0.0            # P(exit burst) per window
    burst_mult: float = 1.0            # innovation multiplier inside burst
    preempt_prob: float = 0.0          # P(preemption event) per window
    preempt_max: int = 0               # max executors lost per event
    skew_growth: float = 1.0           # per-component parallel-work growth
    arrival_rate: float = 0.0          # jobs/round (multi-tenant campaigns)
    pool_size: int = 0                 # global executor pool (0 = unlimited)
    chaos: ChaosSpec = CHAOS_NONE      # controller-side fault plan

    def key(self):
        """Hashable identity used for table caching."""
        return dataclasses.astuple(self)

    def window_tables(self, sim_seed: int) -> Dict:
        return T.window_tables(self, sim_seed)


BASELINE = Scenario()

_REGISTRY: Dict[str, Scenario] = {
    "baseline": BASELINE,
    "node_failure": Scenario(name="node_failure", inject_failures=True),
    "stragglers": Scenario(name="stragglers", straggler_prob=0.12,
                           straggler_scale=0.8),
    "spot_preemption": Scenario(name="spot_preemption", preempt_prob=0.10,
                                preempt_max=6),
    "interference_burst": Scenario(name="interference_burst", burst_prob=0.10,
                                   burst_exit=0.30, burst_mult=4.0),
    "data_skew_drift": Scenario(name="data_skew_drift", skew_growth=1.04),
    "multi_tenant": Scenario(name="multi_tenant", arrival_rate=1.5,
                             pool_size=96),
    # controller-side chaos plans: the cluster stays on the node_failure
    # environment while faults hit the control plane itself
    "chaos_observations": Scenario(
        name="chaos_observations", inject_failures=True,
        chaos=ChaosSpec(name="observations", nan_graphs_every=2,
                        cache_corrupt_every=3)),
    "chaos_model": Scenario(
        name="chaos_model", inject_failures=True,
        chaos=ChaosSpec(name="model", nan_fit_every=3)),
    "chaos_timeouts": Scenario(
        name="chaos_timeouts", inject_failures=True,
        chaos=ChaosSpec(name="timeouts", timeout_every=3, timeout_burst=4)),
    "chaos_crashes": Scenario(
        name="chaos_crashes", inject_failures=True,
        chaos=ChaosSpec(name="crashes", crash_rounds=(2, 5))),
}

SCENARIO_NAMES = tuple(_REGISTRY)


def make_scenario(name: str, seed: int = 0, **overrides) -> Scenario:
    """Look up a named scenario; ``seed`` keys its disturbance tables and
    ``overrides`` compose extra effects onto it (e.g. stragglers + failures:
    ``make_scenario("stragglers", inject_failures=True)``)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; have {SCENARIO_NAMES}")
    return dataclasses.replace(_REGISTRY[name], seed=seed, **overrides)
