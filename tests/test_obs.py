"""Controller flight recorder + metrics registry (ISSUE 9).

Layers under test:

* registry units: fixed-bucket histogram quantiles, merge-restore
  semantics, Prometheus/JSONL exporters;
* recorder units: bounded ring, gating, span lookup and causal links;
* attribute-API compatibility: the service/trainer/cache counters moved
  into the registry behind their original attributes;
* S6 regression: a service checkpoint taken while the breaker is OPEN
  restores breaker state AND the registry's metric labels identically;
* neutrality: with observability disabled a campaign's decisions are
  bit-exact vs the enabled twin and the timed reruns add zero jit traces;
* fused == stepped span parity: replaying the two drivers' (bit-exact)
  telemetry outputs yields identical span streams.
"""
import json
import math

import numpy as np
import pytest

import repro.core.campaign_kernel as ck
from repro import obs
from repro.core import model as enel_model
from repro.core.service import CircuitBreaker, DecisionService
from repro.dataflow import FleetCampaign, JobExperiment
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, HistogramSeries,
                               MetricsRegistry)
from repro.obs.recorder import FlightRecorder


# ---------------------------------------------------------------- registry

def test_histogram_quantiles_without_samples():
    h = HistogramSeries(buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 1.6, 3.0, 3.5, 7.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 6 and abs(s["sum"] - 17.1) < 1e-9
    assert s["min"] == 0.5 and s["max"] == 7.0
    # quantiles interpolate inside the owning bucket, clamped to [min,max]
    assert 1.0 <= s["p50"] <= 4.0
    assert s["p95"] <= 7.0 and s["p99"] <= 7.0
    h.observe(float("nan"))             # non-finite observations are dropped
    assert h.count == 6
    empty = HistogramSeries(buckets=DEFAULT_LATENCY_BUCKETS)
    assert math.isnan(empty.quantile(0.5))


def test_registry_merge_restore():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help").labels(svc="a")
    c.inc(3)
    snap = reg.snapshot()
    c.inc(2)                                     # diverge after snapshot
    reg.counter("t_total").labels(svc="b").inc(7)  # series born later
    reg.gauge("t_new").labels().set(1.0)           # metric born later
    reg.restore(snap)
    assert reg.counter("t_total").labels(svc="a").value == 3  # rewound
    assert reg.counter("t_total").labels(svc="b").value == 7  # untouched
    assert reg.gauge("t_new").labels().value == 1.0           # untouched
    with pytest.raises(ValueError):
        reg.gauge("t_total")                     # kind collision is loud


def test_prometheus_text_exporter():
    reg = MetricsRegistry()
    reg.counter("x_total", "things").labels(job="a").inc(2)
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0)).labels(svc="s")
    h.observe(0.05)
    h.observe(0.5)
    text = reg.prometheus_text()
    assert "# TYPE x_total counter" in text
    assert 'x_total{job="a"} 2' in text
    assert 'lat_seconds_bucket{le="0.1",svc="s"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf",svc="s"} 2' in text
    assert 'lat_seconds_count{svc="s"} 2' in text


# ---------------------------------------------------------------- recorder

def test_recorder_ring_gating_and_jsonl(tmp_path):
    gate = {"on": True}
    rec = FlightRecorder(capacity=4, gate=lambda: gate["on"])
    seqs = [rec.emit("k", i=i) for i in range(6)]
    assert len(rec) == 4 and rec.dropped == 2
    assert rec.find(seqs[0]) is None             # evicted
    assert rec.find(seqs[-1])["attrs"]["i"] == 5
    gate["on"] = False
    assert rec.emit("k", i=99) == -1 and len(rec) == 4
    gate["on"] = True
    path = tmp_path / "spans.jsonl"
    text = rec.to_jsonl(str(path))
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == 4 and lines[-1]["attrs"]["i"] == 5
    assert text.count("\n") == 4
    st = rec.state()
    rec2 = FlightRecorder(capacity=4)
    rec2.load(st)
    assert rec2.stream() == rec.stream()


# ------------------------------------------------- attribute-API counters

def test_service_counters_attribute_api():
    svc = DecisionService(obs_name="t_api")
    svc.decisions += 5
    svc.retries += 2
    assert svc.decisions == 5 and svc.retries == 2
    st = svc.stats()
    assert st["decisions"] == 5 and st["retries"] == 2
    assert st["breaker_state"] == "closed"
    rows = obs.registry().rows(prefix="enel_service_decisions_total")
    assert any(r["labels"] == {"service": "t_api"} and r["value"] == 5
               for r in rows)


def test_breaker_mid_open_checkpoint_restores_state_and_labels():
    """S6: checkpoint while the breaker is OPEN -> restore into a fresh
    service with the same obs label; breaker state, counters AND registry
    series (same labels) all match the moment of the snapshot."""
    svc = DecisionService(obs_name="t_s6")
    for _ in range(svc.breaker.threshold):
        svc.breaker.record(False)
    svc.dispatch_failures += 4
    assert svc.breaker.state == CircuitBreaker.OPEN
    snap = svc.snapshot_state()
    trips0 = svc.breaker.trips

    twin = DecisionService(obs_name="t_s6")      # fresh, label-identical
    assert twin.breaker.state == CircuitBreaker.CLOSED
    twin.restore_state(snap)
    assert twin.breaker.state == CircuitBreaker.OPEN
    assert twin.breaker.trips == trips0
    assert twin.dispatch_failures == 4
    # the one-hot state gauge tracks the restored state under the SAME label
    gauge = obs.registry().get("enel_breaker_state")
    assert gauge.labels(service="t_s6", state="open").value == 1.0
    assert gauge.labels(service="t_s6", state="closed").value == 0.0
    rows = obs.registry().rows(prefix="enel_breaker_trips_total")
    assert any(r["labels"] == {"service": "t_s6"} and r["value"] == trips0
               for r in rows)


def test_obs_snapshot_roundtrips_registry_and_recorder():
    obs.observe("t_rt_seconds", 0.2, phase="x")
    seq = obs.emit("t.span", a=1)
    snap = obs.snapshot()
    assert isinstance(json.dumps(snap, default=str), str)  # pickle/json safe
    obs.observe("t_rt_seconds", 0.9, phase="x")
    obs.restore(snap)
    h = obs.registry().get("t_rt_seconds").labels(phase="x")
    assert h.count == 1                          # rewound to snapshot
    if seq >= 0:
        assert obs.recorder().find(seq) is not None


# ------------------------------------------------------ campaign neutrality

def _twin_campaign(n_profile=2):
    exps = [JobExperiment(k, seed=50 + i, candidate_stride=4)
            for i, k in enumerate(("lr", "kmeans", "gbt"))]
    camp = FleetCampaign(exps, DecisionService(seed=3), engine="batched")
    camp.profile(n_profile)
    return camp


def _decision_trace(all_stats):
    return [(round(s.runtime, 6), tuple(s.scaleouts), round(s.violation, 6),
             s.fallback_decisions, s.n_rescales)
            for run in all_stats for s in run]


@pytest.mark.slow
def test_disabled_obs_is_bit_exact_and_trace_neutral():
    """ENEL_OBS=0 contract on a 3-job stepped campaign: identical decision
    trace, and the disabled run adds exactly as many jit traces as an
    enabled twin on the warmed caches (i.e. zero extra)."""
    with obs.obs_enabled(True):
        stats_on, _ = _twin_campaign().adaptive_campaign(2, "enel", False)
    before = dict(enel_model.TRACE_COUNTS)
    with obs.obs_enabled(False):
        stats_off, _ = _twin_campaign().adaptive_campaign(2, "enel", False)
    delta_off = {k: v - before.get(k, 0)
                 for k, v in enel_model.TRACE_COUNTS.items()
                 if v - before.get(k, 0)}
    before = dict(enel_model.TRACE_COUNTS)
    with obs.obs_enabled(True):
        stats_on2, _ = _twin_campaign().adaptive_campaign(2, "enel", False)
    delta_on = {k: v - before.get(k, 0)
                for k, v in enel_model.TRACE_COUNTS.items()
                if v - before.get(k, 0)}
    assert _decision_trace(stats_off) == _decision_trace(stats_on)
    assert _decision_trace(stats_on2) == _decision_trace(stats_on)
    assert delta_off == delta_on        # disabling adds/removes no compiles


@pytest.mark.slow
def test_fused_telemetry_off_bit_exact():
    """The telemetry=False plan compiles the pre-observability jaxpr: same
    decisions/clocks as the telemetry=True twin, no tel_* outputs, and
    reruns add zero traces."""
    import jax
    p1 = ck.build_plan(_twin_campaign().experiments, 2, telemetry=True)
    p0 = ck.build_plan(_twin_campaign().experiments, 2, telemetry=False)
    _, ys1 = ck.run_fused(p1)
    _, ys0 = ck.run_fused(p0)
    jax.block_until_ready((ys1, ys0))
    assert any(k.startswith("tel_") for k in ys1)
    assert not any(k.startswith("tel_") for k in ys0)
    np.testing.assert_array_equal(np.asarray(ys1["z"]), np.asarray(ys0["z"]))
    np.testing.assert_array_equal(np.asarray(ys1["decided"]),
                                  np.asarray(ys0["decided"]))
    np.testing.assert_array_equal(np.asarray(ys1["clock"]),
                                  np.asarray(ys0["clock"]))
    t0 = enel_model.trace_count("fused_campaign")
    jax.block_until_ready(ck.run_fused(p0)[1])
    jax.block_until_ready(ck.run_fused(p1)[1])
    assert enel_model.trace_count("fused_campaign") == t0


@pytest.mark.slow
def test_fused_vs_stepped_span_parity():
    """Replaying the fused and stepped drivers' telemetry yields identical
    (kind, attrs) span streams — the drivers are bit-exact, so the flight
    recorder must be too."""
    camp = _twin_campaign()
    plan = ck.build_plan(camp.experiments, 2, telemetry=True)
    _, ys_f = ck.run_fused(plan)
    _, ys_s = ck.run_stepped(plan)
    rec = obs.recorder()
    rec.clear()
    n_f = ck.replay_spans(plan, ys_f)
    stream_f = rec.stream()
    rec.clear()
    n_s = ck.replay_spans(plan, ys_s)
    stream_s = rec.stream()
    rec.clear()
    assert n_f == n_s and n_f > 0
    assert stream_f == stream_s
    kinds = {k for k, _ in stream_f}
    assert {"decision.pick", "fit", "run.end"} <= kinds


def test_fallback_spans_link_to_cause():
    """Every decision.fallback span names its cause and links to the
    causing span (guardrail trip / dispatch fault / breaker transition)."""
    rec = obs.recorder()
    rec.clear()
    svc = DecisionService(obs_name="t_cause", max_retries=0)
    calls = {"n": 0}

    def chaos():
        calls["n"] += 1
        from repro.core.service import DispatchTimeout
        raise DispatchTimeout("injected")

    svc.fault_injector = chaos
    exp = JobExperiment("kmeans", seed=2, candidate_stride=4)
    exp.profile(2)
    from repro.dataflow.runner import _future_nodes, _to_graph
    builder = lambda ci, a, z, pr: _to_graph(
        _future_nodes(exp.encoder, exp.job, ci, a, z), pr, ci)
    req = exp.enel.prepare_request(
        graph_builder=builder, next_comp=1,
        n_components=exp.job.n_components, elapsed=10.0,
        current_scaleout=8, target_runtime=exp.target)
    svc.decide([req])
    falls = rec.events("decision.fallback")
    assert falls, "injected dispatch failure must produce fallback spans"
    for ev in falls:
        at = ev["attrs"]
        assert at["cause"] in ("guardrail", "breaker_open",
                               "retries_exhausted", "shed")
        if at["cause_seq"] >= 0:
            cause = rec.find(at["cause_seq"])
            assert cause is not None and cause["seq"] < ev["seq"]
