"""prefill + decode must reproduce full-forward logits (KV caches, Mamba /
mLSTM / sLSTM states, whisper cross-attention).  MoE archs use a high
capacity factor: capacity drops legitimately differ between 16- and 17-token
routing groups (DESIGN.md), so drops are disabled to isolate cache math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models import apply_model, decode_step, init_model, prefill

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_config(get_config(arch))
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = init_model(KEY, cfg)
    b, p, cache_len = 2, 12, 20
    toks = jax.random.randint(KEY, (b, p + 1), 0, cfg.raw_vocab_size)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :p]}
    extra = 0
    if cfg.family == "audio":
        fr = jax.random.normal(KEY, (b, cfg.enc_frames, cfg.d_model))
        batch_full["frames"] = fr
        batch_pre["frames"] = fr
    if cfg.family == "vlm":
        pa = jax.random.normal(KEY, (b, cfg.n_patches, cfg.d_model))
        batch_full["patches"] = pa
        batch_pre["patches"] = pa
        extra = cfg.n_patches
    logits_full, _ = apply_model(params, cfg, batch_full)
    _, cache = prefill(params, cfg, batch_pre, cache_len=cache_len + extra)
    logits_dec, _ = decode_step(params, cfg, cache, toks[:, p:p + 1],
                                jnp.int32(p + extra))
    a = np.asarray(logits_full[:, -1], np.float32)
    d = np.asarray(logits_dec[:, 0], np.float32)
    err = np.max(np.abs(a - d)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 5e-3, (arch, err)


def test_multi_token_decode_chain():
    """Decoding 3 tokens sequentially matches teacher-forced forward."""
    cfg = smoke_config(get_config("xlstm-350m"))
    params = init_model(KEY, cfg)
    b, p, n_new = 1, 8, 3
    toks = jax.random.randint(KEY, (b, p + n_new), 0, cfg.raw_vocab_size)
    logits_full, _ = apply_model(params, cfg, {"tokens": toks})
    _, cache = prefill(params, cfg, {"tokens": toks[:, :p]},
                       cache_len=p + n_new)
    for t in range(n_new):
        logits_dec, cache = decode_step(params, cfg, cache,
                                        toks[:, p + t:p + t + 1],
                                        jnp.int32(p + t))
        a = np.asarray(logits_full[:, p + t], np.float32)
        d = np.asarray(logits_dec[:, 0], np.float32)
        err = np.max(np.abs(a - d)) / (np.max(np.abs(a)) + 1e-9)
        assert err < 5e-3, (t, err)
