"""Optimizer, loss masking, checkpointing, gradient compression, data
pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import TRAIN_4K, get_config, smoke_config
from repro.data.pipeline import DataConfig, global_batch, sample_tokens
from repro.train.checkpoint import (latest_step, prune_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.compression import (compression_ratio, dequantize,
                                     init_error_state, psum_compressed,
                                     quantize)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.train.train import loss_fn

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ optimizer
def test_adamw_minimizes_quadratic():
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=1000, clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params, "float32")
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_lr_schedule_shape():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(opt, jnp.int32(0))) < 0.2
    np.testing.assert_allclose(float(lr_at(opt, jnp.int32(9))), 1.0, atol=0.01)
    assert abs(float(lr_at(opt, jnp.int32(100))) - 0.1) < 0.01


def test_grad_clipping_bounds_update():
    opt = AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params, "float32")
    _, _, m = adamw_update(params, {"w": jnp.full(4, 1e6)}, state, opt)
    assert float(m["grad_norm"]) > 1e5     # reported raw


def test_no_weight_decay_on_vectors():
    opt = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=1)
    params = {"norm": jnp.ones(4), "mat": jnp.ones((4, 4))}
    state = init_opt_state(params, "float32")
    zeros = {"norm": jnp.zeros(4), "mat": jnp.zeros((4, 4))}
    new, _, _ = adamw_update(params, zeros, state, opt)
    np.testing.assert_allclose(np.asarray(new["norm"]), 1.0)   # untouched
    assert float(jnp.max(new["mat"])) < 1.0                     # decayed


# --------------------------------------------------------------------- loss
def test_loss_masks_invalid_targets():
    cfg = smoke_config(get_config("qwen3-0.6b"))
    from repro.models import init_model
    params = init_model(KEY, cfg)
    b, s = 2, 8
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.raw_vocab_size)
    targets = jnp.where(jnp.arange(s) < 4, tokens, -1)
    loss_masked, parts = loss_fn(params, cfg, {"tokens": tokens,
                                               "targets": targets})
    assert float(parts["tokens"]) == b * 4
    assert np.isfinite(float(loss_masked))


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_atomic_prune(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"step": jnp.int32(7)}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, tree, metadata={"dp": 4})
    save_checkpoint(d, 20, tree)
    assert latest_step(d) == 20
    restored, step, meta = restore_checkpoint(d, tree, step=10)
    assert step == 10 and meta == {"dp": 4}
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert int(restored["opt"]["step"]) == 7
    save_checkpoint(d, 30, tree)
    prune_checkpoints(d, keep=2)
    assert latest_step(d) == 30
    with pytest.raises(Exception):
        restore_checkpoint(d, tree, step=10)    # pruned
    # no tmp dirs left behind
    assert not any(p.name.startswith(".tmp") for p in (tmp_path / "ckpt").iterdir())


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "c2")
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"w": jnp.zeros((3, 3))})


# -------------------------------------------------------------- compression
def test_quantization_error_bound():
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(1000) * 5)
    scale = jnp.max(jnp.abs(g)) / 127.0
    err = g - dequantize(quantize(g, scale), scale)
    assert float(jnp.max(jnp.abs(err))) <= float(scale) / 2 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With EF, the accumulated compressed sum tracks the true sum."""
    rng = np.random.RandomState(1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))

    from repro.train.shard_compat import shard_map

    def one_step(g, e):
        f = shard_map(
            lambda gg, ee: psum_compressed(gg[0], ee[0], "data"),
            mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P(), P()))
        return f(g[None], e[None])

    true_acc = np.zeros(64)
    comp_acc = np.zeros(64)
    err = jnp.zeros(64)
    for _ in range(30):
        g = jnp.asarray(rng.randn(64))
        out, err = one_step(g, err)
        comp_acc += np.asarray(out)
        true_acc += np.asarray(g)
    # relative error of the accumulated sum shrinks with EF
    rel = np.abs(comp_acc - true_acc).max() / (np.abs(true_acc).max() + 1e-9)
    assert rel < 0.05, rel


def test_compression_ratio_near_4x():
    params = {"a": jnp.zeros((128, 128)), "b": jnp.zeros((512,))}
    assert 3.5 < compression_ratio(params) < 4.0


# ---------------------------------------------------------------- pipeline
def test_pipeline_deterministic_across_dp_resharding():
    dcfg = DataConfig(seed=7)
    mcfg = smoke_config(get_config("qwen3-0.6b"))
    full = global_batch(dcfg, mcfg, TRAIN_4K, step=3, dp_rank=0, dp_size=1,
                        seq_len=64)
    shards = [global_batch(dcfg, mcfg, TRAIN_4K, step=3, dp_rank=r,
                           dp_size=4, seq_len=64) for r in range(4)]
    np.testing.assert_array_equal(
        full["tokens"], np.concatenate([s["tokens"] for s in shards]))


def test_pipeline_targets_shifted():
    dcfg = DataConfig(seed=0)
    mcfg = smoke_config(get_config("qwen3-0.6b"))
    seq = sample_tokens(dcfg, mcfg, step=0, sample=0, seq_len=32)
    b = global_batch(dcfg, mcfg, TRAIN_4K, step=0, dp_size=TRAIN_4K.global_batch,
                     seq_len=32)
    np.testing.assert_array_equal(b["tokens"][0], seq[:-1])
    np.testing.assert_array_equal(b["targets"][0], seq[1:])
    assert b["tokens"].max() < mcfg.raw_vocab_size
