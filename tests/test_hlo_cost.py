"""The trip-count-aware HLO cost model against programs with known costs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import HloCostModel, analyze


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_counted():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    text = _compile_text(lambda x, y: x @ y, a, b)
    got = analyze(text)["flops"]
    assert abs(got - 2 * 64 * 128 * 256) / (2 * 64 * 128 * 256) < 0.05, got


def test_while_loop_multiplies_flops():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loop(x):
        def body(c, _):
            return c @ c * 1e-3, None
        out, _ = jax.lax.scan(body, x, None, length=17)
        return out

    text = _compile_text(loop, a)
    got = analyze(text)["flops"]
    expected = 17 * 2 * 64 * 64 * 64
    assert abs(got - expected) / expected < 0.05, (got, expected)


def test_nested_scan_multiplies():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def loop(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci * 1e-3, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    text = _compile_text(loop, a)
    got = analyze(text)["flops"]
    expected = 15 * 2 * 32 ** 3
    assert abs(got - expected) / expected < 0.05, (got, expected)


def test_entry_detected_and_bytes_positive():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    text = _compile_text(lambda x: jnp.tanh(x @ x), a)
    m = HloCostModel(text)
    assert m.entry in m.comps
    res = analyze(text)
    assert res["hbm_bytes"] >= 3 * 128 * 128 * 4   # two reads + one write min
