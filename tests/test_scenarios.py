"""Scenario generators produce their documented disturbance signatures;
capacity-capped picks and arrival campaigns respect the executor pool;
cross-context experiment plumbing works end to end."""
import dataclasses

import numpy as np
import pytest

from repro.core.service import apply_capacity
from repro.dataflow.simulator import ClusterSim
from repro.dataflow.workloads import JOBS, scale_job
from repro.sim.scenarios import (BASELINE, SCENARIO_NAMES, Scenario,
                                 make_scenario)
from repro.sim.tables import T_STRAGGLER, W_MAX


def _trace(scenario, seed=17, job_key="kmeans", s=16, runs=1, inject=False):
    """Per-stage runtimes of a seeded run sequence under one scenario."""
    sim = ClusterSim(seed=seed, scenario=scenario)
    out = []
    for _ in range(runs):
        sim.begin_run()
        clock = 0.0
        for k in range(JOBS[job_key].n_components):
            comp = sim.run_component(
                JOBS[job_key], k, clock=clock, start_scaleout=s,
                end_scaleout=s,
                inject_failures=inject or scenario.inject_failures,
                failures_log=[])
            out.extend(
                (k, st.name, np.float32(st.runtime), st.metrics.copy())
                for st in comp.stages)
            clock = comp.stages[-1].start + comp.stages[-1].runtime
    return out


def test_registry_names_and_composition():
    assert set(SCENARIO_NAMES) >= {"baseline", "node_failure", "stragglers",
                                   "spot_preemption", "interference_burst",
                                   "data_skew_drift", "multi_tenant"}
    composed = make_scenario("stragglers", seed=3, inject_failures=True)
    assert composed.straggler_prob > 0 and composed.inject_failures
    with pytest.raises(KeyError):
        make_scenario("nope")


def test_scenario_determinism():
    a = _trace(make_scenario("stragglers", seed=2), runs=2)
    b = _trace(make_scenario("stragglers", seed=2), runs=2)
    assert [x[2] for x in a] == [x[2] for x in b]


def test_straggler_signature():
    """Stragglers multiply some stages by the seeded tail factor and leave
    the rest EXACTLY at baseline (same sim seed => same noise stream)."""
    sc = make_scenario("stragglers", seed=6)
    base = _trace(BASELINE)
    strag = _trace(sc)
    tab = sc.window_tables(17)["straggler"]
    frac_straggled = float(np.mean(tab != 1.0))
    assert 0.03 < frac_straggled < 0.3          # ~straggler_prob of stages
    slowed = 0
    for (_, _, tb, _), (_, _, ts, _) in zip(base, strag):
        assert ts >= tb or np.isclose(ts, tb)
        slowed += ts > tb * 1.001
    assert slowed >= 1
    assert sum(x[2] for x in strag) > sum(x[2] for x in base)


def test_interference_burst_signature():
    """Burst windows multiply the AR(1) innovation: runtimes are pointwise
    >= the same-seed baseline, with a real elevation once a burst hits."""
    sc = make_scenario("interference_burst", seed=8)
    tab = sc.window_tables(17)["burst"]
    assert set(np.unique(tab)) <= {np.float32(1.0),
                                   np.float32(sc.burst_mult)}
    assert (tab > 1.0).any(), "seeded Markov chain must enter a burst"
    base = _trace(BASELINE, runs=2)
    burst = _trace(sc, runs=2)
    assert all(tb2 >= tb1 for (_, _, tb1, _), (_, _, tb2, _)
               in zip(base, burst))
    assert sum(x[2] for x in burst) > sum(x[2] for x in base) * 1.01


def test_spot_preemption_signature():
    """Preempted windows lose 2..preempt_max executors: affected stages run
    at a lower effective scale-out (higher memory pressure in metrics)."""
    sc = make_scenario("spot_preemption", seed=4, preempt_prob=0.5)
    tab = sc.window_tables(17)["preempt"]
    assert tab.max() >= 2 and tab.max() <= sc.preempt_max
    base = _trace(BASELINE, runs=2)
    pre = _trace(sc, runs=2)
    changed = [(b, p) for b, p in zip(base, pre) if b[2] != p[2]]
    assert changed, "some stages must hit a preempted window"
    for b, p in changed:
        assert p[3][3] >= b[3][3]               # gc_frac (mem pressure) up


def test_data_skew_drift_signature():
    """Input growth compounds per component: component 0 is untouched,
    later iterations are strictly slower than the same-seed baseline."""
    sc = make_scenario("data_skew_drift", seed=5)
    base = _trace(BASELINE)
    skew = _trace(sc)
    comp0_b = [x for x in base if x[0] == 0]
    comp0_s = [x for x in skew if x[0] == 0]
    assert [x[2] for x in comp0_b] == [x[2] for x in comp0_s]
    last = max(x[0] for x in base)
    late_b = sum(x[2] for x in base if x[0] >= last - 1)
    late_s = sum(x[2] for x in skew if x[0] >= last - 1)
    assert late_s > late_b * 1.1


def test_node_failure_scenario_forces_injection():
    sc = make_scenario("node_failure", seed=1)
    sim = ClusterSim(seed=3, scenario=sc)
    log = []
    clock = 0.0
    sim.begin_run()
    for k in range(JOBS["kmeans"].n_components):
        comp = sim.run_component(JOBS["kmeans"], k, clock=clock,
                                 start_scaleout=24, end_scaleout=24,
                                 inject_failures=sc.inject_failures,
                                 failures_log=log)
        clock = comp.stages[-1].start + comp.stages[-1].runtime
    assert log, "node_failure scenario must inject kills"


def test_scale_job_scales_parallel_work_only():
    job = JOBS["gbt"]
    big = scale_job(job, 2.0)
    assert big.dataset.size_gb == job.dataset.size_gb * 2
    for a, b in zip(job.prep, big.prep):
        assert b.parallel == a.parallel * 2
        assert b.serial == a.serial and b.comm == a.comm
    # more data -> longer at the same scale-out
    assert big.base_runtime(16) > job.base_runtime(16)


# ------------------------------------------------------------- capacity caps
def _mk_request(cands, valid=None):
    from repro.core.service import DecisionRequest
    cands = np.asarray(cands, np.float32)
    valid = np.ones(len(cands), bool) if valid is None else valid
    return DecisionRequest(
        params={}, base={}, h_onehot=np.zeros((1, 1), np.float32),
        deltas={}, edge_dst=np.zeros((1, 1), np.int32),
        edge_src=np.zeros((1, 1), np.int32),
        edge_valid=np.zeros((1, 1), bool), candidates=cands,
        cand_valid=valid, elapsed=0.0, target=100.0, levels=2,
        candidate_list=[int(c) for c in cands], n_components=1)


def test_apply_capacity_masks_candidates():
    req = _mk_request([4, 8, 16, 24, 36])
    capped = apply_capacity(req, 16)
    assert list(capped.cand_valid) == [True, True, True, False, False]
    assert apply_capacity(req, 36) is req       # cap does not bind
    floor = apply_capacity(req, 2)              # below every candidate
    assert list(floor.cand_valid) == [True, False, False, False, False]


def test_arrival_campaign_pool_invariant():
    """Poisson arrivals into a bounded pool: allocations never exceed the
    pool even when a job is admitted AFTER another's scale-up was granted
    (arrival_rate=1, seed=2 staggers admissions across rounds)."""
    from repro.dataflow import FleetCampaign, JobExperiment
    exps = [JobExperiment("kmeans", seed=70 + i, engine="batched")
            for i in range(3)]
    campaign = FleetCampaign(exps, engine="batched")
    campaign.profile(2)
    stats, trace = campaign.arrival_campaign(pool_size=30, arrival_rate=1.0,
                                             seed=2)
    assert all(st is not None for st in stats), "all jobs must complete"
    assert trace and all(t.pool_used <= t.pool_size for t in trace)
    arrival_rounds = [t.round_idx for t in trace if t.arrivals]
    assert len(arrival_rounds) >= 2, "admissions should stagger"
    for st in stats:
        assert all(s <= 30 for s in st.scaleouts), \
            "a pick exceeded the executor pool"


def test_transfer_experiment_shares_models():
    from repro.dataflow import JobExperiment
    src = JobExperiment("gbt", seed=1)
    dst = JobExperiment("gbt", seed=2, size_scale=1.5,
                        scenario=make_scenario("stragglers", seed=1),
                        share_models_from=src)
    assert dst.trainer is src.trainer and dst.enel is src.enel
    assert dst.job.dataset.size_gb == JOBS["gbt"].dataset.size_gb * 1.5
    assert dst.sim.scenario.name == "stragglers"
