"""End-to-end experiment-protocol integration (reduced scale) + serving."""
import numpy as np
import pytest

import repro.dataflow.runner as runner_mod
from repro.dataflow import JobExperiment, window_stats


@pytest.fixture(scope="module")
def kmeans_exp():
    exp = JobExperiment("kmeans", seed=3)
    exp.profile(6)
    return exp


def test_profiling_sets_target(kmeans_exp):
    assert kmeans_exp.target is not None and kmeans_exp.target > 0
    assert len([s for s in kmeans_exp.stats if s.kind == "profiling"]) == 6


def test_enel_and_ellis_adaptive_runs(kmeans_exp):
    st_e = kmeans_exp.adaptive_run("enel", inject_failures=False)
    st_l = kmeans_exp.adaptive_run("ellis", inject_failures=False)
    for st in (st_e, st_l):
        assert st.runtime > 0
        assert st.violation >= 0
        assert st.scaleouts[0] >= 4
    ws = window_stats(kmeans_exp.stats, 1, 100)
    assert 0.0 <= ws["cvc_mean"] <= 1.0
    assert ws["cvs_mean"] >= 0.0


def test_failure_injector_fires_deterministically():
    # a stage spanning >1 full 90s window at z>4 must contain a window
    # boundary with its kill second inside the stage
    from repro.dataflow.simulator import ClusterSim
    from repro.dataflow.workloads import StageSpec
    log = []
    rec = ClusterSim(seed=0).run_stage(
        StageSpec("long", 250.0, 0.0, 0.0), start_scaleout=8,
        end_scaleout=8, clock=0.0, rescale_overhead=0.0,
        inject_failures=True, failures_log=log)
    assert rec.failures >= 1 and len(log) >= 1


def test_failure_run_records_failures(kmeans_exp):
    # the injector fires once per 90s window ONLY while >4 executors are up
    # and only when the kill second lands inside a stage, so expected kills
    # are ~0.5/run here: any single run can legitimately see zero; a batch
    # of runs cannot (the loop is deterministic for a given model/seed)
    total = 0
    for _ in range(8):
        st = kmeans_exp.adaptive_run("enel", inject_failures=True)
        total += st.n_failures
        if total:
            break
    assert total >= 1


def test_graph_history_grows(kmeans_exp):
    n_comp = kmeans_exp.job.n_components
    assert len(kmeans_exp.graph_history) >= 6 * n_comp


def test_serve_engine_greedy_decode():
    import jax
    from repro.configs import get_config, smoke_config
    from repro.models import init_model
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config(get_config("qwen3-0.6b"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=48)
    reqs = [Request(prompt=np.arange(5) + 2, max_new_tokens=4),
            Request(prompt=np.arange(9) + 2, max_new_tokens=6)]
    stats = eng.serve_wave(reqs)
    assert len(reqs[0].out_tokens) == 4
    assert len(reqs[1].out_tokens) == 6
    assert stats.tokens_out == 10
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out_tokens)
