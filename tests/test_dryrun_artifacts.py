"""The 80-cell dry-run matrix must be complete and green (deliverable e).

These tests read the artifacts produced by ``repro.launch.dryrun`` — rerun
with ``python -m repro.launch.dryrun --both-meshes`` if missing."""
import json
from pathlib import Path

import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

CELLS = [(a, s, mesh) for a in list_archs() for s in SHAPES
         for mesh in ("pod1", "pod2")]


def _load(arch, shape, mesh):
    p = ARTIFACTS / f"{arch}--{shape}--{mesh}.json"
    if not p.exists():
        pytest.skip(f"dry-run artifact missing: {p.name} (run dryrun.py)")
    return json.loads(p.read_text())


@pytest.mark.parametrize("arch,shape,mesh", CELLS)
def test_cell_green(arch, shape, mesh):
    rec = _load(arch, shape, mesh)
    ok, _ = shape_applicable(get_config(arch), SHAPES[shape])
    if not ok:
        assert rec["status"] == "skipped"
        return
    assert rec["status"] == "ok", rec.get("error")
    assert rec["n_devices"] == (512 if mesh == "pod2" else 256)
    assert rec["flops_per_device"] > 0
    assert rec["bytes_per_device"] > 0
    assert rec["roofline"]["t_compute"] > 0
    assert rec["dominant"] in ("t_compute", "t_memory", "t_collective")
    # distributed programs must actually communicate
    assert rec["collective_bytes_per_device"] > 0
    mem = rec["memory_analysis"]
    assert mem.get("argument_size_in_bytes", 1) > 0


def test_multipod_shards_the_pod_axis():
    """The pod axis must reduce per-device load for DP-sharded train cells."""
    n_better = 0
    n_total = 0
    for arch in list_archs():
        r1 = _load(arch, "train_4k", "pod1")
        r2 = _load(arch, "train_4k", "pod2")
        if r1["status"] != "ok" or r2["status"] != "ok":
            continue
        n_total += 1
        if r2["flops_per_device"] < r1["flops_per_device"] * 0.75:
            n_better += 1
    assert n_total >= 8
    assert n_better >= n_total - 1      # DP halves per-device compute
