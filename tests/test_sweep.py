"""Batched candidate-sweep engine + fused graph-prop kernel correctness.

The sweep must reproduce the per-graph predict path exactly: one template per
remaining component + per-candidate deltas, evaluated in a single jit, equals
building every (candidate x component) graph and predicting it individually.
The Pallas kernel must match its pure-numpy ref on random masked DAGs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import model as enel_model
from repro.core.graph import (CTX_DIM, MAX_NODES, N_METRICS, NodeAttrs,
                              build_graph, historical_summaries_batch,
                              historical_summary, materialize_candidate,
                              propagation_depth, stack_graphs, summary_node)
from repro.core.scaling import EnelScaler
from repro.core.training import EnelTrainer

RNG = np.random.RandomState(0)


def _ctx(i):
    return np.tanh(np.random.RandomState(300 + i).randn(CTX_DIM)
                   ).astype(np.float32)


def _nodes(k, a, z, observe=True):
    nodes = []
    for i in range(3):
        s = a if i == 0 else z
        rt = (20.0 / z + 0.5) if observe else None
        met = np.array([0.6, 1.0 / z, 0.2, 0.08, 0.0],
                       np.float32) if observe else None
        nodes.append(NodeAttrs(f"st{i}", _ctx(i), met, s, z,
                               1.0 if a == z else 0.8, rt))
    return nodes


def _graph(nodes, preds, k):
    n = len(nodes)
    edges = [(i, i + 1) for i in range(n - 1)] + \
        [(n + j, 0) for j in range(len(preds))]
    return build_graph(nodes + preds, edges, k)


def _builder(k, a, z, preds):
    return _graph(_nodes(k, a, z, observe=False), preds, k)


@pytest.fixture(scope="module")
def scaler():
    trainer = EnelTrainer(seed=0)
    sc = EnelScaler(trainer, (4, 36))
    for _ in range(6):
        for k in range(5):
            s = int(RNG.choice([4, 8, 16, 24, 32, 36]))
            nodes = _nodes(k, s, s)
            sc.record_component(k, nodes, sum(n.runtime for n in nodes))
    trainer.fit([_graph(_nodes(k, 8, 8), [], k) for k in range(5)], steps=8)
    return sc


def test_sweep_matches_pergraph_predict(scaler):
    """Batched sweep == per-graph EnelTrainer.predict over every candidate."""
    cands = scaler.candidate_scaleouts(8)
    summ = summary_node(_nodes(1, 8, 8), name="P1")
    template, deltas = scaler.build_sweep(
        graph_builder=_builder, next_comp=2, n_components=5,
        current_scaleout=8, candidates=cands, current_summary=summ)
    per = scaler.trainer.predict_sweep(template, deltas)
    assert per.shape == (len(cands), 3)
    for c in range(len(cands)):
        ref = scaler.trainer.predict_stacked(
            materialize_candidate(template, deltas, c))
        np.testing.assert_allclose(per[c], ref, atol=1e-5)


def test_sweep_recommend_matches_pergraph_recommend(scaler):
    """With a candidate-invariant-context builder, the batched recommend and
    the original per-candidate-graph path agree on totals and choice."""
    kw = dict(graph_builder=_builder, next_comp=2, n_components=5,
              elapsed=10.0, current_scaleout=8, target_runtime=25.0,
              current_summary=summary_node(_nodes(1, 8, 8), name="P1"))
    s_new, tot_new, totals_new = scaler.recommend(**kw)
    s_old, tot_old, totals_old = scaler.recommend_pergraph(**kw)
    assert s_new == s_old
    assert set(totals_new) == set(totals_old)
    for s in totals_new:
        np.testing.assert_allclose(totals_new[s], totals_old[s], atol=1e-4)
    np.testing.assert_allclose(tot_new, tot_old, atol=1e-4)


def test_historical_summaries_batch_matches_scalar(scaler):
    hist = scaler.hist_summaries[2]
    targets = np.array([4.0, 9.0, 17.0, 36.0], np.float32)
    batch = historical_summaries_batch(hist, targets, beta=3)
    for i, t in enumerate(targets):
        h = historical_summary(hist, float(t), beta=3)
        np.testing.assert_allclose(batch["context"][i], h.context, atol=1e-6)
        np.testing.assert_allclose(batch["metrics"][i], h.metrics, atol=1e-6)
        np.testing.assert_allclose(batch["start"][i], h.start_scaleout,
                                   atol=1e-5)
        np.testing.assert_allclose(batch["end"][i], h.end_scaleout, atol=1e-5)


def test_propagation_depth():
    g = build_graph([NodeAttrs(f"n{i}", _ctx(i), None, 4, 4)
                     for i in range(4)], [(0, 1), (1, 2), (2, 3)])
    assert propagation_depth(g.adj, g.mask) == 3
    diamond = build_graph([NodeAttrs(f"n{i}", _ctx(i), None, 4, 4)
                           for i in range(4)],
                          [(0, 1), (0, 2), (1, 3), (2, 3)])
    assert propagation_depth(diamond.adj, diamond.mask) == 2
    empty = build_graph([], [])
    assert propagation_depth(empty.adj, empty.mask) == 0


def test_depth_lowered_levels_are_exact():
    """Propagation is a fixed point after `depth` rounds: running the chain
    graph at its exact depth equals running MAX_LEVELS rounds bit-for-bit."""
    params = enel_model.init_enel(jax.random.PRNGKey(1))
    nodes = [NodeAttrs(f"n{i}", _ctx(i),
                       RNG.rand(N_METRICS).astype(np.float32)
                       if i == 0 else None, 4, 4) for i in range(5)]
    g = build_graph(nodes, [(i, i + 1) for i in range(4)])
    batch = {k: jnp.asarray(v) for k, v in stack_graphs([g]).items()}
    depth = propagation_depth(g.adj, g.mask)
    full = enel_model.forward_stacked(params, batch, use_kernel=False)
    low = enel_model.forward_stacked(params, batch, use_kernel=False,
                                     levels=depth)
    np.testing.assert_array_equal(np.asarray(full["metrics"]),
                                  np.asarray(low["metrics"]))
    np.testing.assert_array_equal(np.asarray(full["total_runtime"]),
                                  np.asarray(low["total_runtime"]))


# ------------------------------------------------------------ Pallas kernel
def _random_batch(b, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(b, MAX_NODES, enel_model.X_DIM).astype(np.float32)
    adj = np.tril(rng.rand(b, MAX_NODES, MAX_NODES) < 0.3, -1)
    valid = rng.rand(b, MAX_NODES) < 0.5
    m = rng.rand(b, MAX_NODES, N_METRICS).astype(np.float32)
    return x, adj, m, valid


@pytest.mark.parametrize("b,seed", [(1, 0), (5, 1), (8, 2), (13, 3)])
def test_graph_prop_kernel_matches_ref(b, seed):
    from repro.kernels.graph_prop.ops import graph_prop
    from repro.kernels.graph_prop.ref import graph_prop_ref
    params = enel_model.init_enel(jax.random.PRNGKey(0))
    x, adj, m, valid = _random_batch(b, seed)
    e, mh = graph_prop(params, jnp.asarray(x), jnp.asarray(adj),
                       jnp.asarray(m), jnp.asarray(valid))
    np_params = jax.tree_util.tree_map(np.asarray, params)
    er, mr = graph_prop_ref(np_params, x, adj, m, valid)
    np.testing.assert_allclose(np.asarray(e), er, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mh), mr, atol=1e-5)


def test_forward_stacked_kernel_flag_matches_inline():
    """forward_stacked(use_kernel=True) == inline vmap(forward) path."""
    params = enel_model.init_enel(jax.random.PRNGKey(0))
    graphs = []
    for k in range(3):
        nodes = _nodes(k, 8.0, 16.0, observe=(k == 0))
        preds = [summary_node(_nodes(k, 8, 8), name=f"P{k}")] if k else []
        graphs.append(_graph(nodes, preds, k))
    batch = {k: jnp.asarray(v) for k, v in stack_graphs(graphs).items()}
    out_inline = enel_model.forward_stacked(params, batch, use_kernel=False)
    out_kernel = enel_model.forward_stacked(params, batch, use_kernel=True)
    for key in ("edges", "metrics", "runtime", "acc_runtime",
                "total_runtime"):
        np.testing.assert_allclose(np.asarray(out_inline[key]),
                                   np.asarray(out_kernel[key]),
                                   atol=1e-5, rtol=1e-5)


def test_sweep_with_kernel_flag(scaler):
    """The sweep path also routes through the kernel behind the flag."""
    cands = [4, 12, 20, 36]
    template, deltas = scaler.build_sweep(
        graph_builder=_builder, next_comp=1, n_components=4,
        current_scaleout=12, candidates=cands)
    inline = scaler.trainer.predict_sweep(template, deltas, use_kernel=False)
    fused = scaler.trainer.predict_sweep(template, deltas, use_kernel=True)
    np.testing.assert_allclose(inline, fused, atol=1e-5, rtol=1e-5)
