"""Controller-side chaos injection (repro.sim.chaos).

Contracts under test:

* fault firing is a pure function of (spec seed, experiment seed, index) —
  replay-stable and staggered across a fleet;
* the dispatch-timeout injector's pattern snapshot/restores exactly (the
  service folds it into campaign checkpoints);
* poisoned observation graphs are quarantined by the TrainingCache on
  entry and train with weight 0;
* in-place cache corruption self-heals through ``fit_resident``'s
  quarantine-and-retry sweep, leaving finite params and a finite loss;
* NaN-poisoned model params are detected (``params_finite``) and recovered
  by a scratch retrain;
* a small chaos campaign end-to-end: every decision stays finite and
  in-range while faults fire.
"""
import numpy as np
import pytest

from repro.core.service import DispatchTimeout
from repro.core.training import EnelTrainer
from repro.dataflow import JobExperiment
from repro.sim.chaos import (CHAOS_NONE, ChaosInjector, ChaosSpec,
                             DispatchChaos, make_dispatch_chaos,
                             make_injector)
from repro.sim.scenarios import make_scenario


# ------------------------------------------------------------- determinism
def test_fires_is_deterministic_and_staggered():
    spec = ChaosSpec(name="t", seed=13, nan_fit_every=3)
    a = ChaosInjector(spec, exp_seed=7)
    b = ChaosInjector(spec, exp_seed=8)
    fa = [a._fires(3, i) for i in range(12)]
    fb = [b._fires(3, i) for i in range(12)]
    assert fa == [a._fires(3, i) for i in range(12)]     # pure function
    assert sum(fa) == 4 and sum(fb) == 4                 # every 3rd run
    assert fa != fb                                      # staggered phase


def test_chaos_none_is_inert():
    assert not CHAOS_NONE.active
    assert make_injector(CHAOS_NONE, 0) is None
    assert make_dispatch_chaos(CHAOS_NONE) is None
    spec = ChaosSpec(name="x", crash_rounds=(2,))
    assert spec.active and make_injector(spec, 0) is None


def test_chaos_scenarios_registered():
    for name in ("chaos_observations", "chaos_model", "chaos_timeouts",
                 "chaos_crashes"):
        sc = make_scenario(name, seed=3)
        assert sc.chaos.active
        assert isinstance(sc.key(), tuple)               # stays hashable
    assert make_scenario("baseline").chaos == CHAOS_NONE


def test_dispatch_chaos_pattern_snapshot_restore():
    spec = ChaosSpec(name="t", timeout_every=3, timeout_burst=2)

    def pattern(dc, n):
        out = []
        for _ in range(n):
            try:
                dc()
                out.append(0)
            except DispatchTimeout:
                out.append(1)
        return out

    ref = pattern(DispatchChaos(spec), 20)
    assert sum(ref) > 0 and 0 in ref
    dc = DispatchChaos(spec)
    head = pattern(dc, 8)
    snap = dc.snapshot()
    tail = pattern(dc, 12)
    dc2 = DispatchChaos(spec)
    dc2.restore(snap)
    assert pattern(dc2, 12) == tail
    assert head + tail == ref                            # same stream


# ------------------------------------------------- cache entry quarantine
def _graphs_from_exp(exp, n=3):
    """Real observed component graphs (finite) from the profiling runs."""
    return list(exp.graph_history[:n])


@pytest.fixture(scope="module")
def small_exp():
    exp = JobExperiment("kmeans", seed=41)
    exp.profile(1)
    return exp


def test_poisoned_graphs_are_quarantined_on_entry(small_exp):
    graphs = _graphs_from_exp(small_exp)
    inj = ChaosInjector(ChaosSpec(name="t", nan_graphs_every=1), exp_seed=0)
    poisoned = inj.poison_graphs(graphs, run_idx=0)
    assert inj.graphs_poisoned == 1
    bad = [i for i, g in enumerate(poisoned)
           if not np.isfinite(g.metrics[g.metrics_valid]).all()]
    assert len(bad) == 1
    trainer = EnelTrainer(seed=0, cache_capacity=8)
    trainer.extend_history(poisoned)
    assert trainer.cache.quarantined == 1
    ok = trainer.cache.slot_ok[trainer.cache.latest]
    assert (~ok).sum() == 1
    # quarantined row was replaced by an empty graph: the ring is finite
    host = trainer.cache.stacked_host()
    assert np.isfinite(host["metrics"]).all()
    loss = trainer.fit_resident(steps=16, from_scratch=True)
    assert np.isfinite(loss) and trainer.params_finite()


def test_cache_corruption_self_heals_on_scratch_fit(small_exp):
    graphs = _graphs_from_exp(small_exp)
    trainer = EnelTrainer(seed=1, cache_capacity=8)
    trainer.extend_history(graphs)
    inj = ChaosInjector(ChaosSpec(name="t", cache_corrupt_every=1),
                        exp_seed=0)
    inj.after_fit(trainer, run_idx=0)
    assert inj.cache_rows_corrupted == 1
    host = trainer.cache.stacked_host()
    assert not np.isfinite(host["metrics"]).all()        # bit-rot landed
    q0 = trainer.cache.quarantined
    loss = trainer.fit_resident(steps=16, from_scratch=True)
    assert trainer.cache.quarantined > q0                # sweep fired
    assert np.isfinite(loss) and trainer.params_finite()


def test_param_poison_detected_and_scratch_retrain_recovers(small_exp):
    graphs = _graphs_from_exp(small_exp)
    trainer = EnelTrainer(seed=2, cache_capacity=8)
    trainer.extend_history(graphs)
    trainer.fit_resident(steps=16, from_scratch=True)
    inj = ChaosInjector(ChaosSpec(name="t", nan_fit_every=1), exp_seed=0)
    inj.after_fit(trainer, run_idx=0)
    assert inj.fits_poisoned == 1
    assert not trainer.params_finite()
    # a fine-tune on NaN params can only skip every step (guard holds) ...
    trainer.fit_resident(steps=16, latest_only=True)
    assert trainer.last_skipped_steps > 0
    assert not trainer.params_finite()
    # ... and the cadence's scratch retrain re-initializes and recovers
    loss = trainer.fit_resident(steps=16, from_scratch=True)
    assert np.isfinite(loss) and trainer.params_finite()


# --------------------------------------------------- end-to-end (small)
@pytest.mark.slow
def test_chaos_campaign_decisions_stay_bounded():
    from repro.sim.evaluate import run_chaos_campaign
    rows = run_chaos_campaign("chaos_model", ["kmeans"], profile_runs=2,
                              adaptive_runs=3)
    job_rows = [r for r in rows if r["job"] != "__fleet__"]
    assert job_rows and all(r["nonfinite_decisions"] == 0 for r in job_rows)
    assert sum(r["fallback_decisions"] for r in job_rows) > 0
    fleet = next(r for r in rows if r["job"] == "__fleet__")
    assert fleet["svc_guardrail_trips"] > 0
    assert fleet["poisoned_fits"] > 0
