"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and finiteness (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models import apply_model, init_model, param_count
from repro.train.optimizer import AdamWConfig
from repro.train.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.raw_vocab_size),
        "targets": jax.random.randint(KEY, (b, s), 0, cfg.raw_vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(get_config(arch))
    params = init_model(KEY, cfg)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    logits, aux = apply_model(params, cfg, batch)
    exp_s = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_updates_and_finite(arch):
    cfg = smoke_config(get_config(arch))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(KEY, cfg, opt)
    step = make_train_step(cfg, opt)
    batch = make_batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["opt"]["step"]) == 1
    # at least one parameter actually moved
    before = jax.tree_util.tree_leaves(state["params"])
    after = jax.tree_util.tree_leaves(new_state["params"])
    moved = any(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32)))) > 0
                for a, b in zip(after, before))
    assert moved


def test_grad_accum_matches_single_step():
    cfg = smoke_config(get_config("qwen3-0.6b"))
    cfg = dataclasses.replace(cfg, remat="none")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = make_batch(cfg, b=4, s=16)
    s0 = init_train_state(KEY, cfg, opt)
    s1, m1 = make_train_step(cfg, opt, grad_accum=1)(s0, batch)
    s0b = init_train_state(KEY, cfg, opt)
    s2, m2 = make_train_step(cfg, opt, grad_accum=2)(s0b, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    assert max(jax.tree_util.tree_leaves(d)) < 5e-2   # adam dir ~equal


def test_param_counts_match_published_scale():
    expected_b = {"olmoe-1b-7b": 6.9, "arctic-480b": 477, "gemma3-27b": 27.0,
                  "qwen2.5-14b": 14.8, "jamba-v0.1-52b": 51.6,
                  "pixtral-12b": 12.2, "qwen3-0.6b": 0.60, "gemma2-2b": 2.6}
    for arch, exp in expected_b.items():
        n = param_count(get_config(arch)) / 1e9
        assert abs(n - exp) / exp < 0.15, (arch, n, exp)
