import os
import sys

# tests and benches see ONE device; only launch/dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.RandomState(0)
