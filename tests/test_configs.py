"""Config registry invariants for all ten assigned architectures."""
import pytest

from repro.configs import (SHAPES, all_cells, get_config, get_shape,
                           list_archs, shape_applicable, smoke_config)

EXPECTED = {
    "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
                        n_experts=64, top_k=8, vocab_size=50304),
    "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                        n_experts=128, top_k=2, vocab_size=32000),
    "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16,
                           enc_layers=24, raw_vocab_size=51865),
    "gemma2-2b": dict(n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
                      d_ff=9216, vocab_size=256000),
    "gemma3-27b": dict(n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
                       d_ff=21504, vocab_size=262144),
    "qwen3-0.6b": dict(n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
                       d_ff=3072, vocab_size=151936, qk_norm=True),
    "qwen2.5-14b": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
                        d_ff=13824, vocab_size=152064, qkv_bias=True),
    "pixtral-12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
                        d_ff=14336, vocab_size=131072, n_patches=1024),
    "jamba-v0.1-52b": dict(n_layers=32, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, n_experts=16, top_k=2,
                           vocab_size=65536),
    "xlstm-350m": dict(n_layers=24, d_model=1024, n_heads=4, d_ff=0,
                       vocab_size=50304),
}


def test_registry_complete():
    assert sorted(list_archs()) == sorted(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_published_values(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_layer_pattern_consistency(arch):
    cfg = get_config(arch)
    assert cfg.n_layers == cfg.n_groups * cfg.layer_period + cfg.tail_layers
    # pattern must repeat with the group period so scan params stack
    for j in range(cfg.layer_period):
        kinds = {cfg.layer_kind(g * cfg.layer_period + j)
                 for g in range(cfg.n_groups)}
        fkinds = {cfg.ffn_kind(g * cfg.layer_period + j)
                  for g in range(cfg.n_groups)}
        assert len(kinds) == 1 and len(fkinds) == 1, (arch, j)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_tp_divisibility_for_sharding(arch):
    """Dims that the sharding rules split 16-way must divide."""
    cfg = get_config(arch)
    assert cfg.vocab_size % 16 == 0
    assert cfg.d_model % 16 == 0
    if cfg.d_ff:
        assert cfg.d_ff % 16 == 0
    if cfg.n_experts:
        assert cfg.n_experts % 16 == 0


def test_cells_40_with_8_skips():
    cells = all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = [a for a, s, ok, _ in cells if s == "long_500k" and ok]
    assert sorted(runnable_long) == ["jamba-v0.1-52b", "xlstm-350m"]


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_smoke_config_preserves_family(arch):
    cfg = get_config(arch)
    sm = smoke_config(cfg)
    assert sm.family == cfg.family
    assert sm.layer_period == cfg.layer_period
    assert sm.n_layers <= 2 * cfg.layer_period
    assert (sm.n_experts > 0) == (cfg.n_experts > 0)
