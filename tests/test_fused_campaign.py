"""Fused whole-campaign kernel: parity, grounding, checkpointing, guardrails.

The contract under test is layered:

* ``run_fused == run_stepped`` BIT-EXACT — the scan and the python loop
  drive the identical jitted step body, so any divergence is a real bug;
* the fused sim is grounded in the engine: replaying the fused z-schedule
  through ``BatchedClusterSim.run_full`` on a twin fleet reproduces the
  stage runtimes and clocks bit-exactly (same RNG stream contract);
* a mid-campaign checkpoint/resume materializes identical traces;
* the in-scan guardrails keep every decision finite under nan_fit chaos;
* compile count is bounded: a second campaign with the same static plan
  shape adds ZERO new traces.
"""
import numpy as np
import pytest

import repro.core.campaign_kernel as ck
from repro.core.model import trace_count
from repro.core.service import DecisionService
from repro.dataflow import FleetCampaign, JobExperiment
from repro.dataflow.fleet import FusedCheckpoint, materialize_fused
from repro.sim.chaos import ChaosInjector, ChaosSpec
from repro.sim.scenarios import make_scenario

# three adaptive runs: with PROFILE_RUNS=3 the retrain cadence scratches at
# run 1 and the nan_fit injector (seed 7, every=2) poisons right after it,
# so run 2's decisions exercise the in-scan fallback guardrail
N_RUNS = 3
PROFILE_RUNS = 3


def _campaign(job_keys, seed=7, stride=4, scenarios=None, chaos_on=(),
              seeds=None):
    exps = []
    for i, k in enumerate(job_keys):
        sc = make_scenario(scenarios[i]) if scenarios else None
        exps.append(JobExperiment(
            k, seed=seeds[i] if seeds else seed + i,
            candidate_stride=stride, scenario=sc))
    camp = FleetCampaign(exps, DecisionService(seed=3), engine="batched")
    camp.profile(PROFILE_RUNS)
    for i in chaos_on:   # attach AFTER profiling, like the chaos suite
        exps[i].chaos = ChaosInjector(ChaosSpec(name="t", nan_fit_every=2),
                                      exp_seed=exps[i].seed)
    return camp


def _assert_tree_equal(t1, t2, msg=""):
    import jax
    l1 = jax.tree_util.tree_leaves_with_path(t1)
    l2 = jax.tree_util.tree_leaves_with_path(t2)
    assert len(l1) == len(l2)
    for (p, a), (_, b) in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg}{p}")


FIXTURE_JOBS = dict(job_keys=("kmeans", "gbt", "kmeans"), seeds=(7, 8, 7),
                    scenarios=("node_failure", "baseline", "node_failure"),
                    chaos_on=(0,))


@pytest.fixture(scope="module")
def fused_pair():
    """One 3-slot fleet (kmeans repeated with the same seed — exercising
    class/history dedup) under node_failure + nan_fit chaos, with the
    fused and stepped drivers run over the SAME plan (module-scoped:
    compiling the step body once serves every parity assertion below)."""
    camp = _campaign(**FIXTURE_JOBS)
    plan = ck.build_plan(camp.experiments, N_RUNS)
    c_f, ys_f = ck.run_fused(plan)
    c_s, ys_s = ck.run_stepped(plan)
    return camp, plan, (c_f, ys_f), (c_s, ys_s)


def test_fused_matches_stepped_bitwise(fused_pair):
    _, _, (c_f, ys_f), (c_s, ys_s) = fused_pair
    _assert_tree_equal(ys_f, ys_s, "ys:")
    _assert_tree_equal(c_f, c_s, "carry:")


def test_plan_dedups_structural_tables(fused_pair):
    """Slots 0 and 2 share (job, seed), so they share one class and one
    history table: the plan carries G=2 < J=3 structural classes."""
    _, plan, _, _ = fused_pair
    assert plan.dev["obs_ctx"].shape[0] == 2
    assert plan.dev["hob_ctx"].shape[0] == 2
    np.testing.assert_array_equal(np.asarray(plan.dev["cls"]),
                                  [0, 1, 0])


def test_fused_chaos_guardrail(fused_pair):
    """nan_fit chaos poisons the resident params in-scan; every decision
    still leaves the scan finite (clamped, counted) and the fallback pick
    answers at least one decision while the model is poisoned."""
    _, plan, (c_f, ys_f), _ = fused_pair
    nonfin = np.asarray(c_f["nonfinite"])
    assert (nonfin == 0).all(), nonfin
    assert np.isfinite(np.asarray(ys_f["s_next"])).all()
    assert np.asarray(c_f["fallbacks"])[0] > 0        # chaos-poisoned job
    assert np.asarray(plan.dev["poison_at"]).any()    # chaos actually fired


def test_fused_grounded_in_run_full(fused_pair):
    """Replaying the fused a/z schedule through the engine's run_full on a
    TWIN fleet reproduces stage runtimes and final clocks bit-exactly."""
    _, plan, (c_f, ys_f), _ = fused_pair
    twin = _campaign(**FIXTURE_JOBS)
    backend = twin.experiments[0].backend
    c_max = plan.static.c_max
    a = np.asarray(ys_f["a"]).astype(np.int64)        # (T, J)
    z = np.asarray(ys_f["z"]).astype(np.int64)
    rt = np.asarray(ys_f["rt"])                       # (T, s_max, J)
    clock = np.asarray(ys_f["clock"])
    for r in range(N_RUNS):
        t0 = r * c_max
        a_sched = a[t0:t0 + c_max].T.copy()           # (J, c_max)
        z_sched = z[t0:t0 + c_max].T.copy()
        res = backend.run_full(a_sched, z_sched)
        for j, (comps, _) in enumerate(res):
            exp = twin.experiments[j]
            for k, comp in enumerate(comps):
                for i, stage in enumerate(comp.stages):
                    np.testing.assert_array_equal(
                        np.float32(stage.runtime), rt[t0 + k, i, j],
                        err_msg=f"run {r} job {j} comp {k} stage {i}")
            nc = exp.job.n_components
            np.testing.assert_array_equal(
                np.float32(backend.slot_state(j)["clock"]),
                clock[t0 + nc - 1, j])


def test_fused_checkpoint_resume_trace_identical(fused_pair, tmp_path):
    """A campaign split at every run boundary, checkpointed to disk and
    resumed, materializes the same traces as the uninterrupted scan."""
    camp, plan, (c_f, ys_f), _ = fused_pair
    carry = ck.init_carry(plan)
    c_max = plan.static.c_max
    carry, ys1 = ck.run_fused(plan, carry, 0, c_max)
    import jax
    host_ys = jax.tree_util.tree_map(np.asarray, ys1)
    ckpt = FusedCheckpoint(step=c_max, n_steps=plan.n_steps,
                           carry=ck.carry_to_host(carry), ys=host_ys)
    p = tmp_path / "fused.ckpt"
    ckpt.save(str(p))
    ckpt2 = FusedCheckpoint.load(str(p))
    carry2 = ck.carry_from_host(ckpt2.carry)
    carry2, ys2 = ck.run_fused(plan, carry2, ckpt2.step, plan.n_steps)
    joined = {k: np.concatenate([ckpt2.ys[k], np.asarray(ys2[k])])
              for k in ckpt2.ys}
    _assert_tree_equal(joined, ys_f, "resumed ys:")
    _assert_tree_equal(carry2, c_f, "resumed carry:")
    stats_resumed = materialize_fused(plan, joined)
    stats_once = materialize_fused(
        plan, jax.tree_util.tree_map(np.asarray, ys_f))
    assert repr(stats_resumed) == repr(stats_once)


def test_fused_compile_count_bounded(fused_pair):
    """Same static plan shape => ZERO new traces (scan + step already
    compiled); the fused campaign's compile count is bounded by the
    bucket-ladder rungs, not by runs or jobs."""
    _, plan, _, _ = fused_pair
    before = trace_count("fused_campaign")
    ck.run_fused(plan)
    ck.run_stepped(plan, stop=1)
    assert trace_count("fused_campaign") == before


def test_fused_campaign_entry_and_write_back():
    """FleetCampaign.fused_campaign returns adaptive_campaign-shaped stats
    and syncs model/ring/backend state so stepped runs continue after it."""
    camp = _campaign(("kmeans", "gbt"))
    exps = camp.experiments
    rings0 = [e.trainer.cache.count for e in exps]
    runs_seen0 = [e.trainer.runs_seen for e in exps]
    stats, report = camp.fused_campaign(N_RUNS)
    assert len(stats) == N_RUNS and len(stats[0]) == len(exps)
    assert (report.nonfinite == 0).all()
    for j, e in enumerate(exps):
        assert e._run_idx == PROFILE_RUNS + N_RUNS
        assert e.trainer.runs_seen == runs_seen0[j] + N_RUNS
        assert e.trainer.cache.count == min(
            rings0[j] + N_RUNS * e.job.n_components,
            e.trainer.cache.capacity)
        for r in range(N_RUNS):
            st = stats[r][j]
            assert st.kind == "enel" and st.runtime > 0.0
            assert st.run_idx == PROFILE_RUNS + r + 1
            assert e.stats[-N_RUNS + r] is st
    # the written-back state supports continuing on the stepped path
    post = camp.adaptive_round()
    assert all(s.runtime > 0 and np.isfinite(s.runtime) for s in post)
    assert [s.run_idx for s in post] == \
        [PROFILE_RUNS + N_RUNS + 1] * len(exps)


def test_fused_campaign_checkpointed_matches_single_pass():
    camp_a = _campaign(("kmeans",), seed=21)
    stats_a, rep_a = camp_a.fused_campaign(N_RUNS, write_back=False)
    camp_b = _campaign(("kmeans",), seed=21)
    stats_b, rep_b = camp_b.fused_campaign(N_RUNS, write_back=False,
                                           checkpoint_every_runs=1)
    assert len(rep_b.checkpoints) == N_RUNS - 1
    _assert_tree_equal(rep_a.ys, rep_b.ys, "segmented ys:")
    assert repr(stats_a) == repr(stats_b)
    stats_c, rep_c = camp_b.resume_fused_campaign(
        rep_b.plan, rep_b.checkpoints[-1], write_back=False)
    _assert_tree_equal(rep_a.ys, rep_c.ys, "resumed ys:")
    assert repr(stats_a) == repr(stats_c)


def test_build_plan_rejections():
    camp = _campaign(("kmeans",), seed=33)
    exp = camp.experiments[0]
    exp.chaos = ChaosInjector(ChaosSpec(name="t", nan_graphs_every=2),
                              exp_seed=0)
    with pytest.raises(ValueError, match="nan_fit"):
        ck.build_plan(camp.experiments, 1)
    exp.chaos = None
    exp.scale_cap = 16
    with pytest.raises(ValueError, match="capacity"):
        ck.build_plan(camp.experiments, 1)
    exp.scale_cap = None
    tgt, exp.target = exp.target, None
    with pytest.raises(ValueError, match="profile"):
        ck.build_plan(camp.experiments, 1)
    exp.target = tgt


@pytest.mark.slow
def test_fused_matches_stepped_fleet8_scenarios():
    """Full acceptance sweep: a fleet of 8 slots covering all four paper
    jobs (each twice, sharing class tables), node_failure on half and
    nan_fit chaos on one — fused == stepped bit-exact."""
    camp = _campaign(
        ("lr", "mpc", "kmeans", "gbt") * 2,
        seeds=(11, 12, 13, 14, 11, 12, 13, 14),
        scenarios=("baseline", "node_failure", "node_failure", "baseline",
                   "baseline", "node_failure", "node_failure", "baseline"),
        chaos_on=(2,))
    plan = ck.build_plan(camp.experiments, N_RUNS)
    c_f, ys_f = ck.run_fused(plan)
    c_s, ys_s = ck.run_stepped(plan)
    _assert_tree_equal(ys_f, ys_s, "ys:")
    _assert_tree_equal(c_f, c_s, "carry:")
    assert (np.asarray(c_f["nonfinite"]) == 0).all()
