"""Tests for the later substrate additions: mamba_scan kernel, straggler
detector, compressed-DP step, MoE dispatch properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dependency")
from hypothesis import given, settings
from hypothesis import strategies as st

RNG = np.random.RandomState(0)


# -------------------------------------------------------- mamba_scan kernel
@pytest.mark.parametrize("b,s,d,n,chunk,bd",
                         [(2, 128, 64, 8, 32, 32), (1, 64, 128, 16, 64, 64),
                          (1, 96, 32, 4, 16, 32)])
def test_mamba_scan_kernel_vs_ref(b, s, d, n, chunk, bd):
    from repro.kernels.mamba_scan.kernel import mamba_scan
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    decay = jnp.asarray(RNG.uniform(0.5, 1.0, (b, s, d, n)), jnp.float32)
    drive = jnp.asarray(RNG.randn(b, s, d, n) * 0.1, jnp.float32)
    c = jnp.asarray(RNG.randn(b, s, n), jnp.float32)
    out = mamba_scan(decay, drive, c, chunk=chunk, block_d=bd)
    ref = mamba_scan_ref(decay, drive, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_selective_scan_matches_model_mamba_math():
    """ops.selective_scan == the associative-scan inside models/ssm."""
    from repro.kernels.mamba_scan.ops import selective_scan
    b, s, d, n = 1, 64, 32, 4
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (b, s, d)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (d, n)), jnp.float32)
    x = jnp.asarray(RNG.randn(b, s, d), jnp.float32)
    bb = jnp.asarray(RNG.randn(b, s, n), jnp.float32)
    cc = jnp.asarray(RNG.randn(b, s, n), jnp.float32)
    y = selective_scan(dt, a, x, bb, cc, chunk=16, block_d=32)

    decay = jnp.exp(dt[..., None] * a)
    drive = (dt * x)[..., None] * bb[:, :, None, :]

    def comb(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])

    _, h = jax.lax.associative_scan(comb, (decay, drive), axis=1)
    y_ref = jnp.einsum("bsdn,bsn->bsd", h, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- stragglers
def test_straggler_detection_and_replacement():
    from repro.train.stragglers import StragglerConfig, StragglerDetector
    det = StragglerDetector(StragglerConfig(mad_k=4.0, replace_after=2))
    rng = np.random.RandomState(0)
    for step in range(8):
        for g in range(8):
            t = 1.0 + rng.randn() * 0.01 + (3.0 if g == 5 else 0.0)
            det.heartbeat(g, t)
    assert det.flagged() == [5]
    assert det.severity() > 1.0          # ~3x slower than the median
    det.flagged()
    assert det.should_replace() == [5]


def test_straggler_quiet_cluster_flags_nothing():
    from repro.train.stragglers import StragglerDetector
    det = StragglerDetector()
    rng = np.random.RandomState(1)
    for _ in range(10):
        for g in range(6):
            det.heartbeat(g, 1.0 + rng.randn() * 0.02)
    assert det.flagged() == []
    assert det.severity() < 0.2


# --------------------------------------------------- compressed DP step
def test_dp_step_compressed_matches_uncompressed():
    from jax.sharding import Mesh
    from repro.configs import get_config, smoke_config
    from repro.train.dp_step import make_dp_train_step
    from repro.train.optimizer import AdamWConfig
    from repro.train.train import init_train_state

    cfg = smoke_config(get_config("qwen3-0.6b"))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.raw_vocab_size),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                           cfg.raw_vocab_size)}
    step_c, init_extra = make_dp_train_step(cfg, opt, mesh, compress=True)
    step_u, _ = make_dp_train_step(cfg, opt, mesh, compress=False)
    err = init_extra(state["params"])
    s1, err1, m1 = step_c(state, err, batch)
    state2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    s2, _, m2 = step_u(state2, err, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    # int8 grads steer the same direction: params end up close after 1 step
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    assert max(jax.tree_util.tree_leaves(d)) < 5e-3


# ----------------------------------------------------------- MoE properties
@given(st.integers(2, 5), st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_drops_bounded(seed, k):
    """No expert ever receives more than its capacity; outputs stay finite."""
    import dataclasses
    from repro.configs import get_config, smoke_config
    from repro.models.moe import capacity, init_moe, moe_ffn
    cfg = dataclasses.replace(smoke_config(get_config("olmoe-1b-7b")),
                              top_k=k, capacity_factor=1.0)
    p = init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model))
    out = moe_ffn(p, cfg, x)
    assert out["out"].shape == x.shape
    assert np.isfinite(np.asarray(out["out"], np.float32)).all()
    assert float(out["aux_loss"]) >= 0.99   # >= 1 at/near balance


def test_moe_group_size_invariance_without_drops():
    """With generous capacity, routing-group size must not change outputs."""
    import dataclasses
    from repro.configs import get_config, smoke_config
    from repro.models.moe import init_moe, moe_ffn
    base = dataclasses.replace(smoke_config(get_config("olmoe-1b-7b")),
                               capacity_factor=16.0)
    p = init_moe(jax.random.PRNGKey(0), base)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, base.d_model))
    cfg_big = dataclasses.replace(base, moe_group=64)
    cfg_small = dataclasses.replace(base, moe_group=16)
    y1 = moe_ffn(p, cfg_big, x)["out"]
    y2 = moe_ffn(p, cfg_small, x)["out"]
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=2e-2)
