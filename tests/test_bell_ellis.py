"""Bell (initial allocation) and Ellis (baseline scaler) behaviour."""
import numpy as np

from repro.core.bell import (BellModel, NonParametricModel, ParametricModel,
                             initial_scaleout)
from repro.core.ellis import EllisScaler


def _ernest(s, noise=0.0, rng=None):
    t = 5.0 + 120.0 / s + 2.0 * np.log(s) + 0.05 * s
    if noise and rng is not None:
        t = t + rng.randn(*np.shape(s)) * noise
    return t


def test_parametric_fits_ernest_curve():
    s = np.array([4, 8, 12, 16, 24, 32, 36], float)
    m = ParametricModel().fit(s, _ernest(s))
    pred = m.predict(np.array([6.0, 20.0]))
    np.testing.assert_allclose(pred, _ernest(np.array([6.0, 20.0])), rtol=0.05)


def test_nonparametric_interpolates_exactly_at_knots():
    s = np.array([4, 8, 16.0])
    t = np.array([10, 6, 4.0])
    m = NonParametricModel().fit(s, t)
    np.testing.assert_allclose(m.predict(s), t, rtol=1e-6)


def test_bell_cv_prefers_parametric_on_smooth_data():
    rng = np.random.RandomState(0)
    s = np.array([4, 6, 8, 12, 16, 20, 24, 28, 32, 36], float)
    bell = BellModel().fit(s, _ernest(s, 0.1, rng))
    assert bell.choice == "parametric"


def test_bell_cv_prefers_nonparametric_on_steppy_data():
    s = np.array([4, 6, 8, 12, 16, 20, 24, 28, 32, 36], float)
    t = np.where(s < 16, 100.0, 10.0)          # non-Ernest cliff
    bell = BellModel().fit(s, t)
    assert bell.choice == "nonparametric"


def test_initial_scaleout_smallest_compliant():
    hist = [(s, _ernest(s)) for s in [4, 8, 12, 16, 24, 32, 36]]
    target = _ernest(16) + 0.5
    s = initial_scaleout(hist, target, (4, 36))
    assert s <= 16
    assert _ernest(s) <= target * 1.1


def test_ellis_recommend_meets_target():
    ellis = EllisScaler((4, 36), rescale_overhead=2.0)
    rng = np.random.RandomState(0)
    for _ in range(6):
        for comp in range(5):
            for s in (4, 8, 16, 24, 32):
                ellis.observe_component(comp, s, _ernest(s, 0.2, rng) / 5)
    ellis.refit()
    target = sum(_ernest(24) / 5 for _ in range(5)) * 1.2
    s, predicted = ellis.recommend(next_comp=0, n_components=5, elapsed=0.0,
                                   current_scaleout=4, target_runtime=target)
    assert predicted <= target
    assert 4 <= s <= 36          # smallest compliant scale-out in range


def test_ellis_falls_back_to_argmin_when_infeasible():
    ellis = EllisScaler((4, 8))
    for comp in range(3):
        for s in (4, 6, 8):
            ellis.observe_component(comp, s, 100.0 / s)
    ellis.refit()
    s, pred = ellis.recommend(next_comp=0, n_components=3, elapsed=0.0,
                              current_scaleout=4, target_runtime=1.0)
    assert s == 8                # least violation = max scale-out here
