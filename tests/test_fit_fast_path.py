"""Online-learning fast path: custom-VJP graph-prop kernel + TrainingCache.

Gradient parity: differentiating ``enel_loss`` through the fused Pallas
kernel (custom VJP -> backward Pallas kernel) must agree with the inline
``vmap(forward)`` autodiff path on random masked DAGs, and the raw op's VJP
must match ``jax.grad`` through the pure-jnp reference.  Cache equivalence:
incremental ring-buffer appends must reproduce a one-shot ``stack_graphs``
and the resident fit must match the legacy list-of-graphs fit when metric
dropout is disabled.  (No hypothesis dependency — plain seeded RNG.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import model as enel_model
from repro.core.graph import (CTX_DIM, MAX_NODES, N_METRICS, NodeAttrs,
                              TrainingCache, build_graph, stack_graphs)
from repro.core.training import EnelTrainer, enel_loss


def _random_full_batch(b, seed):
    """Stacked training batch over random masked DAGs (all loss targets)."""
    rng = np.random.RandomState(seed)
    mask = rng.rand(b, MAX_NODES) < 0.8
    mask[:, 0] = True
    adj = np.tril(rng.rand(b, MAX_NODES, MAX_NODES) < 0.3, -1)
    return {
        "context": np.tanh(rng.randn(b, MAX_NODES, CTX_DIM)
                           ).astype(np.float32),
        "metrics": rng.rand(b, MAX_NODES, N_METRICS).astype(np.float32),
        "metrics_valid": (rng.rand(b, MAX_NODES) < 0.5) & mask,
        "a_raw": rng.uniform(1, 36, (b, MAX_NODES)).astype(np.float32),
        "z_raw": rng.uniform(1, 36, (b, MAX_NODES)).astype(np.float32),
        "r": rng.uniform(0.5, 1.0, (b, MAX_NODES)).astype(np.float32),
        "runtime": rng.uniform(1, 30, (b, MAX_NODES)).astype(np.float32),
        "runtime_valid": (rng.rand(b, MAX_NODES) < 0.7) & mask,
        "overhead": rng.uniform(0, 3, (b, MAX_NODES)).astype(np.float32),
        "overhead_valid": (rng.rand(b, MAX_NODES) < 0.3) & mask,
        "adj": adj,
        "mask": mask,
        "is_summary": (rng.rand(b, MAX_NODES) < 0.2) & mask,
    }


def _tree_allclose(a, b, atol, rtol):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=rtol)


# ------------------------------------------------------------ gradient parity
@pytest.mark.parametrize("b,seed", [(8, 1)])
def test_vjp_matches_jnp_reference(b, seed):
    """Raw op: custom-VJP grads == jax.grad through graph_prop_ref_jnp for
    params, x AND m_obs under random output cotangents."""
    from repro.kernels.graph_prop.ops import graph_prop
    from repro.kernels.graph_prop.ref import graph_prop_ref_jnp
    rng = np.random.RandomState(seed)
    x = rng.randn(b, MAX_NODES, enel_model.X_DIM).astype(np.float32)
    adj = np.tril(rng.rand(b, MAX_NODES, MAX_NODES) < 0.3, -1)
    valid = rng.rand(b, MAX_NODES) < 0.5
    m = rng.rand(b, MAX_NODES, N_METRICS).astype(np.float32)
    ce = rng.randn(b, MAX_NODES, MAX_NODES).astype(np.float32)
    cm = rng.randn(b, MAX_NODES, N_METRICS).astype(np.float32)
    params = enel_model.init_enel(jax.random.PRNGKey(seed))

    def scalar(fn):
        def f(p, xx, mm):
            e, mh = fn(p, xx, mm)
            return jnp.sum(e * ce) + jnp.sum(mh * cm)
        return jax.value_and_grad(f, argnums=(0, 1, 2))

    vk, gk = scalar(lambda p, xx, mm: graph_prop(
        p, xx, jnp.asarray(adj), mm, jnp.asarray(valid)))(
        params, jnp.asarray(x), jnp.asarray(m))
    vr, gr = scalar(lambda p, xx, mm: graph_prop_ref_jnp(
        p, xx, adj, mm, valid))(params, jnp.asarray(x), jnp.asarray(m))
    np.testing.assert_allclose(float(vk), float(vr), rtol=1e-5)
    _tree_allclose(gk, gr, atol=1e-4, rtol=1e-3)


def test_enel_loss_grad_kernel_matches_inline():
    """jax.grad(enel_loss) through forward_stacked(use_kernel=True) == the
    inline vmap(forward) autodiff path on random masked DAGs."""
    params = enel_model.init_enel(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in _random_full_batch(6, 0).items()}
    gi = jax.grad(lambda p: enel_loss(p, batch, None, False)[0])(params)
    gk = jax.grad(lambda p: enel_loss(p, batch, None, True)[0])(params)
    li = enel_loss(params, batch, None, False)[0]
    lk = enel_loss(params, batch, None, True)[0]
    np.testing.assert_allclose(float(li), float(lk), rtol=1e-5)
    _tree_allclose(gi, gk, atol=2e-4, rtol=2e-3)


def test_fit_resident_kernel_flag_matches_inline():
    """The resident fit reaches the same parameters through either route
    (fused kernel w/ custom VJP vs inline), i.e. training really can run
    behind ENEL_GRAPH_PROP_KERNEL."""
    def run(use_kernel):
        enel_model.set_graph_prop_kernel(use_kernel)
        try:
            tr = EnelTrainer(seed=0, cache_capacity=8)
            tr.extend_history([_chain_graph(k, seed=k) for k in range(4)])
            loss = tr.fit_resident(steps=8, metric_dropout=0.0)
        finally:
            enel_model.set_graph_prop_kernel(False)
        return loss, tr.params

    l_inline, p_inline = run(False)
    l_kernel, p_kernel = run(True)
    np.testing.assert_allclose(l_inline, l_kernel, rtol=1e-4)
    _tree_allclose(p_inline, p_kernel, atol=1e-5, rtol=1e-4)


def test_legacy_fit_kernel_flag_matches_inline():
    """EnelTrainer.fit (legacy restack path) honours the kernel flag too."""
    graphs = [_chain_graph(k, seed=k) for k in range(2)]

    def run(use_kernel):
        enel_model.set_graph_prop_kernel(use_kernel)
        try:
            tr = EnelTrainer(seed=0)
            loss = tr.fit(graphs, steps=8, metric_dropout=0.0)
        finally:
            enel_model.set_graph_prop_kernel(False)
        return loss, tr.params

    l_inline, p_inline = run(False)
    l_kernel, p_kernel = run(True)
    np.testing.assert_allclose(l_inline, l_kernel, rtol=1e-4)
    _tree_allclose(p_inline, p_kernel, atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------- cache equivalence
def _chain_graph(k, n=4, seed=0, max_nodes=MAX_NODES):
    r = np.random.RandomState(100 + seed)
    nodes = [NodeAttrs(f"n{i}", np.tanh(r.randn(CTX_DIM)).astype(np.float32),
                       r.rand(N_METRICS).astype(np.float32), 4 + i, 8, 0.9,
                       runtime=5.0 + i, overhead=0.5 if i == 0 else None)
             for i in range(n)]
    return build_graph(nodes, [(i, i + 1) for i in range(n - 1)], k,
                       max_nodes=max_nodes)


def test_cache_incremental_equals_one_shot_stack():
    graphs = [_chain_graph(k, seed=k) for k in range(5)]
    cache = TrainingCache(capacity=8, max_nodes=8)
    cache.extend(graphs[:2])
    cache.extend(graphs[2:])
    host = cache.stacked_host()
    ref = stack_graphs(graphs)
    for k, v in host.items():
        r = ref[k][:, :8, :8] if k == "adj" else \
            (ref[k][:, :8] if ref[k].ndim > 1 else ref[k])
        np.testing.assert_array_equal(v[:5], r, err_msg=k)


def test_cache_ring_wraparound_keeps_newest():
    graphs = [_chain_graph(k, seed=k) for k in range(7)]
    cache = TrainingCache(capacity=4, max_nodes=8)
    for g in graphs:
        cache.extend([g])
    host = cache.stacked_host()
    ref = stack_graphs(graphs[-4:])
    np.testing.assert_array_equal(host["runtime"], ref["runtime"][:, :8])
    np.testing.assert_array_equal(host["adj"], ref["adj"][:, :8, :8])
    assert cache.count == 4


def test_cache_grows_node_slots():
    cache = TrainingCache(capacity=4, max_nodes=4)
    cache.extend([_chain_graph(0, n=3, seed=0)])
    cache.extend([_chain_graph(1, n=7, seed=1)])       # forces 4 -> 8 slots
    assert cache.max_nodes == 8
    host = cache.stacked_host()
    ref = stack_graphs([_chain_graph(0, n=3, seed=0),
                        _chain_graph(1, n=7, seed=1)])
    np.testing.assert_array_equal(host["mask"], ref["mask"][:, :8])
    np.testing.assert_array_equal(host["metrics"], ref["metrics"][:, :8])


def test_fit_resident_matches_legacy_fit_no_dropout():
    """With per-step dropout off, training on the ring == the legacy host
    restack path (same graphs, same step count, same seed)."""
    graphs = [_chain_graph(k, seed=k) for k in range(5)]
    tr_res = EnelTrainer(seed=0, cache_capacity=8)
    tr_res.extend_history(graphs)
    l_res = tr_res.fit_resident(steps=8, metric_dropout=0.0)
    tr_leg = EnelTrainer(seed=0)
    l_leg = tr_leg.fit(graphs, steps=8, metric_dropout=0.0)
    np.testing.assert_allclose(l_res, l_leg, rtol=1e-4)
    _tree_allclose(tr_res.params, tr_leg.params, atol=1e-5, rtol=1e-3)


def test_fit_resident_latest_only_ignores_older_history():
    """Fine-tuning on the newest extend() == training on just those graphs."""
    old = [_chain_graph(k, seed=k) for k in range(3)]
    new = [_chain_graph(k, seed=10 + k) for k in range(2)]
    tr_a = EnelTrainer(seed=0, cache_capacity=8)
    tr_a.extend_history(old)
    tr_a.extend_history(new)
    l_a = tr_a.fit_resident(steps=8, metric_dropout=0.0, latest_only=True)
    tr_b = EnelTrainer(seed=0, cache_capacity=8)
    tr_b.extend_history(new)
    l_b = tr_b.fit_resident(steps=8, metric_dropout=0.0, latest_only=True)
    np.testing.assert_allclose(l_a, l_b, rtol=1e-5)
    _tree_allclose(tr_a.params, tr_b.params, atol=1e-6, rtol=1e-5)


def test_fit_resident_per_step_dropout_trains():
    tr = EnelTrainer(seed=0, cache_capacity=8)
    tr.extend_history([_chain_graph(k, seed=k) for k in range(5)])
    l1 = tr.fit_resident(steps=8, metric_dropout=0.5)
    l2 = tr.fit_resident(steps=64, metric_dropout=0.5)
    assert np.isfinite(l1) and np.isfinite(l2)
    assert l2 < l1


# ------------------------------------------------- sweep template device cache
def test_template_device_cache_skips_unchanged_uploads():
    from repro.core.scaling import EnelScaler

    def builder(k, a, z, preds):
        nodes = [NodeAttrs(f"st{i}", np.tanh(
            np.random.RandomState(i).randn(CTX_DIM)).astype(np.float32),
            None, a if i == 0 else z, z, 1.0 if a == z else 0.8)
            for i in range(3)]
        edges = [(i, i + 1) for i in range(2)] + \
            [(3 + j, 0) for j in range(len(preds))]
        return build_graph(nodes + list(preds), edges, k)

    trainer = EnelTrainer(seed=0)
    sc = EnelScaler(trainer, (4, 12), candidate_stride=4)
    kw = dict(graph_builder=builder, next_comp=1, n_components=3,
              elapsed=5.0, current_scaleout=8, target_runtime=50.0)
    s1, t1, totals1 = sc.recommend(**kw)
    first_transfers = sc.template_cache.transfers
    assert first_transfers > 0 and sc.template_cache.skips == 0
    s2, t2, totals2 = sc.recommend(**kw)
    # identical decision context -> every base array re-upload is skipped
    assert sc.template_cache.transfers == first_transfers
    assert sc.template_cache.skips >= 10
    assert (s1, t1) == (s2, t2) and totals1 == totals2


# ----------------------------------------------------- non-finite fit guard
def test_nonfinite_guard_skips_poisoned_legacy_fit():
    """Legacy fit() on a batch with a NaN runtime target: every Adam step's
    loss is non-finite, the in-scan guard skips them all, and the params
    stay exactly the (finite) pre-fit values."""
    bad = _chain_graph(0, seed=0)
    bad.runtime[bad.runtime_valid] = np.nan
    tr = EnelTrainer(seed=3)
    before = jax.tree_util.tree_map(np.asarray, tr.params)
    loss = tr.fit([bad], steps=8, metric_dropout=0.0)
    assert not np.isfinite(loss)
    assert tr.last_skipped_steps == 8
    assert tr.nonfinite_steps == 8
    assert tr.poisoned_fits == 1
    _tree_allclose(tr.params, before, atol=0, rtol=0)
    assert tr.params_finite()


def test_fit_resident_quarantine_retry_heals_in_place_corruption():
    """NaN written straight into resident ring rows (past the entry
    quarantine): the first fit skips every step, sweeps the ring, and the
    automatic retry trains to a finite loss on the healed buffers."""
    tr = EnelTrainer(seed=4, cache_capacity=8)
    tr.extend_history([_chain_graph(k, seed=k) for k in range(4)])
    tr.cache.buffers["metrics"] = \
        tr.cache.buffers["metrics"].at[1].set(jnp.nan)
    q0 = tr.cache.quarantined
    loss = tr.fit_resident(steps=8, from_scratch=True, metric_dropout=0.0)
    assert np.isfinite(loss)
    assert tr.cache.quarantined == q0 + 1
    assert not tr.cache.slot_ok[1]
    assert tr.params_finite()
    # without the retry the poisoned fit reports non-finite and skips all
    tr2 = EnelTrainer(seed=4, cache_capacity=8)
    tr2.extend_history([_chain_graph(k, seed=k) for k in range(4)])
    tr2.cache.buffers["metrics"] = \
        tr2.cache.buffers["metrics"].at[1].set(jnp.nan)
    loss2 = tr2.fit_resident(steps=8, from_scratch=True,
                             metric_dropout=0.0, _retry=False)
    assert not np.isfinite(loss2)
    assert tr2.last_skipped_steps == 8
    assert tr2.params_finite()
