"""Hypothesis property tests for the context-encoding layer (eqs. 1-2)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dependency")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.autoencoder import train_autoencoder
from repro.core.encoding import (DEFAULT_L, binarizer, encode_property,
                                 hasher, is_natural)


@given(st.text(min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_hasher_unit_sphere_or_zero(s):
    q = hasher(s)
    norm = np.linalg.norm(q)
    assert q.shape == (DEFAULT_L,)
    assert abs(norm - 1.0) < 1e-5 or norm == 0.0   # eq.2 projection


@given(st.text(min_size=1, max_size=60))
@settings(max_examples=30, deadline=None)
def test_hasher_deterministic(s):
    np.testing.assert_array_equal(hasher(s), hasher(s))


@given(st.integers(min_value=0, max_value=2 ** DEFAULT_L - 1))
@settings(max_examples=100, deadline=None)
def test_binarizer_roundtrip(p):
    bits = binarizer(p)
    assert set(np.unique(bits)).issubset({0.0, 1.0})
    decoded = int(sum(int(b) << i for i, b in enumerate(bits)))
    assert decoded == p                             # unique encoding


@given(st.one_of(st.integers(min_value=0, max_value=10 ** 6),
                 st.text(min_size=1, max_size=30)))
@settings(max_examples=60, deadline=None)
def test_lambda_prefix_flags_method(p):
    vec = encode_property(p)
    assert vec.shape == (DEFAULT_L + 1,)
    assert vec[0] == (1.0 if is_natural(p) else 0.0)  # eq.1 lambda


def test_binarizer_domain_guard():
    with pytest.raises(ValueError):
        binarizer(2 ** DEFAULT_L)
    with pytest.raises(ValueError):
        binarizer(-1)


def test_autoencoder_reconstructs():
    rng = np.random.RandomState(0)
    props = [rng.randint(0, 1000) for _ in range(20)] + \
        [f"job param {i} iterations" for i in range(20)]
    from repro.core.encoding import encode_properties
    vecs = encode_properties(props)
    _, loss = train_autoencoder(vecs, steps=300)
    base = float(np.mean(vecs ** 2))               # predict-zero baseline
    assert loss < base * 0.5
