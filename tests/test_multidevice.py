"""Multi-device semantics via subprocess (fresh jax with 8 fake devices):
sharding rules, elastic re-mesh + resharded restore, compressed DP psum."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharding_rules_across_archs():
    print(run_py("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config, get_shape
        from repro.launch.mesh import _mk
        from repro.launch.shardings import logical_rules, param_spec
        shape = get_shape("train_4k")
        # tp=4: heads divide for olmoe (16) and qwen2.5 (40)
        mesh4 = _mk((2, 4), ("data", "model"))
        r = logical_rules(get_config("olmoe-1b-7b"), mesh4, shape)
        assert r["tp_heads"] == "model" and r["ep"] == "model", r
        r = logical_rules(get_config("qwen2.5-14b"), mesh4, shape)
        assert r["tp_heads"] == "model", r
        # tp=3: 40 heads / 8 kv heads do NOT divide -> seq-parallel attention
        mesh3 = _mk((2, 3), ("data", "model"))
        r = logical_rules(get_config("qwen2.5-14b"), mesh3, shape)
        assert r["tp_heads"] is None and r["kv_seq"] == "model", r
        r = logical_rules(get_config("jamba-v0.1-52b"), mesh3,
                          get_shape("long_500k"))
        assert r["dp"] is None and r["cache_seq"] == ("data", "model"), r
        # divisibility guard drops axes that do not divide
        class L:  # fake leaf
            ndim = 2
            shape = (7, 1024)
        from jax.tree_util import DictKey
        spec = param_spec(mesh4, (DictKey("attn"), DictKey("wq")), L)
        assert spec == P(None, "model"), spec   # 7 % 2 != 0 -> dropped
        print("RULES-OK")
    """))


@pytest.mark.slow
def test_elastic_rescale_and_failure_recovery(tmp_path):
    out = run_py(f"""
        import jax
        from repro.configs import get_config, smoke_config, TRAIN_4K
        import dataclasses
        from repro.train.elastic import ElasticConfig, ElasticTrainer
        cfg = smoke_config(get_config("qwen3-0.6b"))
        shape = dataclasses.replace(TRAIN_4K, seq_len=32, global_batch=8)
        ecfg = ElasticConfig(target_runtime=3600.0, n_components=4,
                             steps_per_component=2, dp_choices=(2, 4, 8),
                             ckpt_dir=r"{tmp_path}/ck", fail_at_component=2,
                             seed=0)
        tr = ElasticTrainer(cfg, shape, ecfg)
        res = tr.run()
        assert res["final_step"] == 8, res
        assert res["n_rescales"] >= 1, res       # the injected failure
        assert len(set(res["dp_trace"])) >= 2, res
        print("ELASTIC-OK", res["dp_trace"])
    """)
    assert "ELASTIC-OK" in out


@pytest.mark.slow
def test_compressed_psum_multidevice():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import _mk
        from repro.train.compression import psum_compressed
        mesh = _mk((8,), ("data",))
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(8, 256))
        e = jnp.zeros((8, 256))
        f = jax.shard_map(lambda gg, ee: psum_compressed(gg[0], ee[0], "data"),
                          mesh=mesh, in_specs=(P("data"), P("data")),
                          out_specs=(P(), P("data")), check_vma=False)
        out, err = f(g, e)
        true = np.mean(np.asarray(g), axis=0)
        rel = np.abs(np.asarray(out) - true).max() / (np.abs(true).max())
        assert rel < 0.05, rel
        print("PSUM-OK", rel)
    """)
    assert "PSUM-OK" in out


@pytest.mark.slow
def test_pipeline_parallel_lowering():
    """Optional PP feature: GPipe-style ppermute schedule lowers and runs."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline import pipelined_forward, make_stage_params
        from repro.launch.mesh import _mk
        mesh = _mk((4,), ("stage",))
        params = make_stage_params(jax.random.PRNGKey(0), n_stages=4, d=16)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))  # (mb, b, d)
        y = pipelined_forward(params, x, mesh)
        y_ref = x
        import repro.train.pipeline as pl_mod
        for i in range(4):
            y_ref = pl_mod.stage_fn({k: v[i] for k, v in params.items()}, y_ref)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        print("PP-OK")
    """)
    assert "PP-OK" in out
