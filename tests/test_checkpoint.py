"""Campaign checkpoint/restore: a killed controller resumes trace-identical.

The contract (ISSUE: fault-tolerant fleet control plane): a FleetCampaign
killed at an arbitrary lockstep round and restored from its latest periodic
checkpoint produces EXACTLY the decision trace, runtimes and stats of an
uninterrupted campaign — mid-run generators are rebuilt by replaying their
event logs against run-start snapshots, then the sim backend slots are
pinned to their checkpoint-time state.  Checkpointing itself must be
observer-free (enabling it changes nothing).
"""
import numpy as np
import pytest

from repro.core.service import DecisionService
from repro.dataflow import FleetCampaign, JobExperiment
from repro.dataflow.fleet import CampaignCheckpoint

FOUR_JOBS = ("lr", "mpc", "kmeans", "gbt")
TWO_JOBS = ("kmeans", "gbt")


def _campaign(job_keys, seed=7, stride=4):
    exps = [JobExperiment(k, seed=seed + i, engine="batched",
                          candidate_stride=stride)
            for i, k in enumerate(job_keys)]
    c = FleetCampaign(exps, DecisionService(seed=3), engine="batched")
    c.profile(2)
    return c


def _trace(all_stats):
    return [(round(s.runtime, 4), round(s.violation, 4),
             tuple(s.scaleouts), s.n_failures, s.n_rescales,
             s.fallback_decisions, s.shed_requests)
            for run in all_stats for s in run]


# ---------------------------------------------- kill + restore == unbroken
@pytest.mark.slow
def test_four_job_campaign_killed_at_round3_resumes_identically(tmp_path):
    """The ISSUE's acceptance scenario: 4-job campaign, controller killed
    after 3 lockstep rounds, restored from the checkpoint — the completed
    campaign matches an uninterrupted one exactly.  The checkpoint also
    survives a pickle round-trip to disk."""
    ref, _ = _campaign(FOUR_JOBS).adaptive_campaign(2, "enel", True)

    crash = _campaign(FOUR_JOBS)
    out, ckpts = crash.adaptive_campaign(2, "enel", True,
                                         checkpoint_every=1,
                                         stop_after_round=3)
    assert out is None and ckpts           # crashed, checkpoints taken
    path = tmp_path / "campaign.ckpt"
    ckpts[-1].save(str(path))
    loaded = CampaignCheckpoint.load(str(path))
    assert loaded.mid_run == ckpts[-1].mid_run
    assert loaded.round_idx == ckpts[-1].round_idx

    resumed, _ = crash.resume_adaptive_campaign(loaded)
    assert _trace(resumed) == _trace(ref)


def test_checkpointing_is_observer_free():
    """checkpoint_every=1 and checkpoint_every=0 produce identical stats:
    snapshotting never perturbs RNG streams, caches or device state."""
    plain, _ = _campaign(TWO_JOBS).adaptive_campaign(2, "enel", False)
    ckpt, cks = _campaign(TWO_JOBS).adaptive_campaign(2, "enel", False,
                                                      checkpoint_every=1)
    assert len(cks) > 1
    assert _trace(plain) == _trace(ckpt)


def test_resilient_campaign_survives_multiple_crashes():
    plain, _ = _campaign(TWO_JOBS).adaptive_campaign(3, "enel", True)
    hard, restores = _campaign(TWO_JOBS).adaptive_campaign_resilient(
        3, "enel", True, crash_rounds=(2, 5), checkpoint_every=1)
    assert restores == 2
    assert _trace(hard) == _trace(plain)


# -------------------------------------------------- arrival-campaign resume
def test_arrival_campaign_crash_resume_matches():
    kw = dict(pool_size=40, arrival_rate=1.2, inject_failures=False,
              seed=11, max_rounds=48)
    c_ref = _campaign(("kmeans", "gbt", "lr"), seed=21)
    ref_stats, ref_trace = c_ref.arrival_campaign(**kw)

    c = _campaign(("kmeans", "gbt", "lr"), seed=21)
    out, _ = c.arrival_campaign(**kw, checkpoint_every=2,
                                stop_after_round=5)
    assert out is None and c.checkpoints
    stats, trace = c.resume_arrival_campaign(c.checkpoints[-1])

    def key(st):
        return None if st is None else (round(st.runtime, 4),
                                        tuple(st.scaleouts))
    assert [key(s) for s in stats] == [key(s) for s in ref_stats]
    assert [(t.round_idx, t.arrivals, t.active, t.pool_used,
             t.capped_decisions) for t in trace] == \
           [(t.round_idx, t.arrivals, t.active, t.pool_used,
             t.capped_decisions) for t in ref_trace]


# ------------------------------------------------------- state round-trips
def test_job_experiment_snapshot_restore_roundtrip():
    """restore_state + an adaptive run reproduces the run the original
    experiment would have done (single-job checkpoint unit contract)."""
    a = JobExperiment("gbt", seed=5, engine="batched", candidate_stride=4)
    a.profile(2)
    snap = a.snapshot_state()
    ref = a.adaptive_run("enel", inject_failures=True)

    a.restore_state(snap)
    again = a.adaptive_run("enel", inject_failures=True)
    assert np.float32(again.runtime) == np.float32(ref.runtime)
    assert again.scaleouts == ref.scaleouts
    # the checkpoint stayed pristine: restore twice, same result
    a.restore_state(snap)
    third = a.adaptive_run("enel", inject_failures=True)
    assert third.scaleouts == ref.scaleouts
