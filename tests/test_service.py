"""Fleet decision service: shape bucketing, sparse engine, batched dispatch.

The contracts under test:

* padding a sweep to the bucket ladders changes NOTHING — the padded dense
  sweep equals the unpadded one bit-for-bit on the real JOBS builders;
* the sparse-edge engine equals the dense engine on random masked DAGs;
* one batched service dispatch over a multi-job fleet returns exactly the
  decisions the jobs would get from sequential per-job ``recommend``;
* the template device cache is a bounded LRU;
* the on-device pick replicates the host pick's tie-breaking.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import model as enel_model
from repro.core.graph import (CTX_DIM, N_METRICS, NodeAttrs, SweepTemplate,
                              bucket_sweep, build_graph, stack_graphs,
                              summary_node, sweep_edge_list)
from repro.core.model import pick_candidate, sweep_sparse_totals
from repro.core.scaling import EnelScaler, _TemplateDeviceCache
from repro.core.service import DecisionService
from repro.dataflow import FleetCampaign, JobExperiment
from repro.dataflow.runner import (_component_nodes, _future_nodes, _to_graph)


# --------------------------------------------------------------- fixtures
FLEET_JOBS = ("lr", "kmeans", "gbt")


@pytest.fixture(scope="module")
def fleet_exps():
    """Three profiled job experiments (distinct classes) sharing nothing."""
    exps = []
    for i, key in enumerate(FLEET_JOBS):
        exp = JobExperiment(key, seed=20 + i)
        exp.profile(2)
        exps.append(exp)
    return exps


def _decision_kwargs(exp):
    job = exp.job
    builder = lambda ci, a, z, pr: _to_graph(
        _future_nodes(exp.encoder, job, ci, a, z), pr, ci)
    comp = exp.sim.run_component(job, 0, clock=0.0, start_scaleout=8,
                                 end_scaleout=8, inject_failures=False,
                                 failures_log=[])
    summ = summary_node(_component_nodes(exp.encoder, job, comp), name="P0")
    return dict(graph_builder=builder, next_comp=1,
                n_components=job.n_components, elapsed=comp.runtime,
                current_scaleout=8, target_runtime=exp.target,
                current_summary=summ)


# ------------------------------------------------- padded == unpadded (0.0)
@pytest.mark.parametrize("job_key", ["lr", "mpc", "kmeans", "gbt"])
def test_bucketed_sweep_matches_unpadded_exactly(job_key):
    """Dense sweep on ladder-padded template/deltas == unpadded sweep with
    0.0 deviation, on the real JOBS builders, across K/C shapes that cross
    the bucket boundaries (incl. exact-rung K and small tails)."""
    exp = JobExperiment(job_key, seed=7)
    job = exp.job
    builder = lambda ci, a, z, pr: _to_graph(
        _future_nodes(exp.encoder, job, ci, a, z), pr, ci)
    # a little history so H-summary slots participate too
    rng = np.random.RandomState(0)
    for _ in range(4):
        for k in range(job.n_components):
            s = float(rng.choice([4, 8, 16, 24, 36]))
            nodes = _future_nodes(exp.encoder, job, k, s, s)
            for nd in nodes:
                nd.metrics = rng.rand(N_METRICS).astype(np.float32)
                nd.runtime = float(5.0 + rng.rand())
            exp.enel.record_component(k, nodes, 10.0)
    n = job.n_components
    # (next_comp, stride): K crosses rungs (incl. K==rung exactly), C varies
    cases = [(1, 2), (max(1, n - 12), 2), (n - 4, 2), (n - 1, 2), (1, 8)]
    for next_comp, stride in cases:
        exp.enel.candidate_stride = stride
        candidates = exp.enel.candidate_scaleouts(9)
        template, deltas = exp.enel.build_sweep(
            graph_builder=builder, next_comp=next_comp, n_components=n,
            current_scaleout=9, candidates=candidates)
        ref = exp.enel.trainer.predict_sweep(template, deltas)
        padded_t, padded_d, (c_real, k_real) = bucket_sweep(template, deltas)
        assert padded_d["a_raw"].shape[0] >= c_real
        assert padded_t.base["mask"].shape[0] >= k_real
        per = enel_model.sweep_per_component(
            exp.enel.trainer.params,
            {k: jnp.asarray(v) for k, v in padded_t.base.items()},
            jnp.asarray(padded_t.h_onehot),
            {k: jnp.asarray(v) for k, v in padded_d.items()},
            use_kernel=False, levels=padded_t.levels)
        got = np.asarray(per)[:c_real, :k_real]
        np.testing.assert_array_equal(got, ref)       # 0.0 deviation
        # padded components must read out EXACTLY 0
        tail = np.asarray(per)[:, k_real:]
        np.testing.assert_array_equal(tail, np.zeros_like(tail))


# ------------------------------------------------------ sparse == dense
def _random_graphs(seed, count=7, max_nodes=8):
    rng = np.random.RandomState(seed)
    graphs = []
    for k in range(count):
        n = rng.randint(1, max_nodes)
        nodes = [NodeAttrs(
            f"n{i}", np.tanh(rng.randn(CTX_DIM)).astype(np.float32),
            rng.rand(N_METRICS).astype(np.float32) if rng.rand() < 0.5
            else None,
            float(rng.randint(2, 30)), float(rng.randint(2, 30)),
            time_fraction=float(0.5 + 0.5 * rng.rand()),
            is_summary=bool(rng.rand() < 0.3)) for i in range(n)]
        edges = [(i, j) for j in range(n) for i in range(j)
                 if rng.rand() < 0.4]
        graphs.append(build_graph(nodes, edges, k, max_nodes=max_nodes))
    return graphs


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_engine_matches_dense(seed):
    graphs = _random_graphs(seed)
    batch = stack_graphs(graphs)
    params = enel_model.init_enel(jax.random.PRNGKey(seed))
    dense = enel_model.forward_stacked(
        params, {k: jnp.asarray(v) for k, v in batch.items()},
        use_kernel=False)["total_runtime"]
    dst, src, val = sweep_edge_list(batch)
    sparse = sweep_sparse_totals(
        params, {k: jnp.asarray(v) for k, v in batch.items()},
        jnp.asarray(dst), jnp.asarray(src), jnp.asarray(val))
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------- batched dispatch == sequential picks
def test_service_matches_sequential_recommend(fleet_exps):
    """3-job fleet: one batched decide == per-job sequential recommend."""
    service = DecisionService()
    kwargs = [_decision_kwargs(exp) for exp in fleet_exps]
    # warm the probe caches so both paths below see identical builder state
    for exp, kw in zip(fleet_exps, kwargs):
        exp.enel.recommend(**kw)
        exp.enel.prepare_request(**kw)
    sequential, requests = [], []
    for i, (exp, kw) in enumerate(zip(fleet_exps, kwargs)):
        # identical encoder RNG draws for both engines' graph builds
        exp.encoder.rng = np.random.RandomState(1000 + i)
        sequential.append(exp.enel.recommend(**kw))
        exp.encoder.rng = np.random.RandomState(1000 + i)
        requests.append(exp.enel.prepare_request(**kw))
    results = service.decide(requests)
    assert service.dispatches >= 1
    assert service.decisions == len(fleet_exps)
    for (s_seq, tot_seq, totals_seq), res in zip(sequential, results):
        assert res.scaleout == s_seq
        assert set(res.totals) == set(totals_seq)
        for s in totals_seq:
            np.testing.assert_allclose(res.totals[s], totals_seq[s],
                                       rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.predicted, tot_seq,
                                   rtol=1e-4, atol=1e-3)


def test_result_per_component_lazy_shape(fleet_exps):
    exp = fleet_exps[0]
    kw = _decision_kwargs(exp)
    req = exp.enel.prepare_request(**kw)
    res = DecisionService().decide([req])[0]
    per = res.per_component
    assert per.shape == (len(req.candidate_list), req.n_components)
    s, predicted, totals = exp.enel.apply_decision(req, res)
    assert s == res.scaleout
    # scaler-side lazy diagnostics mirror the result
    np.testing.assert_array_equal(exp.enel.last_per_component, per)


def test_fleet_campaign_round_batches(fleet_exps):
    """A campaign round over 3 jobs batches concurrent decisions and yields
    the same RunStats surface as individual adaptive runs."""
    campaign = FleetCampaign(fleet_exps)
    stats = campaign.adaptive_round("enel", inject_failures=False)
    assert len(stats) == len(fleet_exps)
    for st, exp in zip(stats, fleet_exps):
        assert st.kind == "enel" and st.runtime > 0
        assert st.decide_calls > 0
        assert st.cache_transfers >= 0 and st.cache_skips >= 0
        assert exp.stats[-1] is st
    assert campaign.service.batched_away > 0      # real cross-job batching
    assert campaign.service.decisions == sum(st.decide_calls for st in stats)


# ----------------------------------------------------------- LRU bound
def _mini_template(k, n=4, seed=0):
    rng = np.random.RandomState(seed)
    base = {
        "context": rng.rand(k, n, CTX_DIM).astype(np.float32),
        "metrics": rng.rand(k, n, N_METRICS).astype(np.float32),
        "metrics_valid": np.ones((k, n), bool),
        "a_raw": np.ones((k, n), np.float32),
        "z_raw": np.ones((k, n), np.float32),
        "r": np.ones((k, n), np.float32),
        "adj": np.zeros((k, n, n), bool),
        "mask": np.ones((k, n), bool),
        "is_summary": np.zeros((k, n), bool),
    }
    flags = np.zeros((k, n), bool)
    return SweepTemplate(base=base, h_onehot=np.zeros((k, n), np.float32),
                         a_follows_a=flags, a_follows_z=flags,
                         z_follows_a=flags, z_follows_z=flags,
                         r_eq=base["r"], r_neq=base["r"])


def test_template_device_cache_lru_eviction():
    cache = _TemplateDeviceCache(max_slots=2)
    for k in (2, 3, 4):
        cache.adopt(_mini_template(k), n_candidates=6)
    assert len(cache._slots) == 2
    assert cache.evictions == 1
    # re-adopting an evicted key re-uploads (it was dropped)
    before = cache.transfers
    cache.adopt(_mini_template(2), n_candidates=6)
    assert cache.transfers > before
    assert cache.evictions == 2
    # touching a live key keeps it resident (LRU order, no new eviction)
    cache.adopt(_mini_template(2), n_candidates=6)
    assert cache.evictions == 2


# ------------------------------------------- double-buffered dispatch parity
def test_double_buffered_dispatch_matches_sync(fleet_exps):
    """Overlapped stack-next-while-device-computes dispatch returns exactly
    the synchronous path's decisions (same picks, totals, diagnostics)."""
    kwargs = [_decision_kwargs(exp) for exp in fleet_exps]
    for exp, kw in zip(fleet_exps, kwargs):
        exp.enel.prepare_request(**kw)          # warm probe caches
    def requests():
        reqs = []
        for i, (exp, kw) in enumerate(zip(fleet_exps, kwargs)):
            exp.encoder.rng = np.random.RandomState(2000 + i)
            reqs.append(exp.enel.prepare_request(**kw))
        return reqs
    sync = DecisionService(double_buffer=False)
    buf = DecisionService(double_buffer=True)
    res_s = sync.decide(requests())
    res_b = buf.decide(requests())
    assert sync.dispatches == buf.dispatches
    for a, b in zip(res_s, res_b):
        assert a.scaleout == b.scaleout
        assert a.predicted == b.predicted
        assert a.totals == b.totals
        np.testing.assert_array_equal(a.per_component, b.per_component)


# ----------------------------------------------- cross-engine runner parity
def test_runner_parity_numpy_vs_batched_engine():
    """Same seed -> identical RunRecords and decisions through the FULL
    runner (profiling targets, adaptive scale-out trajectory) whether the
    simulation runs on the numpy event loop or the vectorized engine."""
    from repro.dataflow.runner import JobExperiment
    en = JobExperiment("gbt", seed=9, engine="numpy")
    eb = JobExperiment("gbt", seed=9, engine="batched")
    en.profile(2)
    eb.profile(2)
    for a, b in zip(en.stats, eb.stats):
        assert np.float32(a.runtime) == np.float32(b.runtime)
    assert en.target == eb.target
    sa = en.adaptive_run("enel", inject_failures=True)
    sb = eb.adaptive_run("enel", inject_failures=True)
    assert np.float32(sa.runtime) == np.float32(sb.runtime)
    assert sa.scaleouts == sb.scaleouts
    assert sa.n_failures == sb.n_failures
    assert sa.n_rescales == sb.n_rescales


# ------------------------------------------------------- device pick parity
def test_pick_candidate_matches_host_pick():
    cand = np.array([4, 6, 8, 10, 12, 12], np.float32)
    valid = np.array([1, 1, 1, 1, 1, 0], bool)
    for seed in range(30):
        rng = np.random.RandomState(seed)
        totals = (rng.rand(6) * 30 + 5).astype(np.float32)
        target = float(rng.rand() * 40)
        t_host = {float(s): float(t)
                  for s, t, v in zip(cand, totals, valid) if v}
        host_s, _, _ = EnelScaler._pick(
            sorted(t_host), {s: t_host[s] for s in t_host}, target)
        idx = int(pick_candidate(jnp.asarray(cand), jnp.asarray(valid),
                                 jnp.asarray(totals), jnp.asarray(target)))
        assert valid[idx]
        assert float(cand[idx]) == host_s
