"""End-to-end behaviour of the paper's system: Enel's dynamic scaling meets
runtime targets that a static allocation misses, and reacts to failures."""
import numpy as np
import pytest

from repro.core.graph import NodeAttrs, build_graph, historical_summary, summary_node
from repro.core.scaling import EnelScaler
from repro.core.training import EnelTrainer
from repro.core.graph import CTX_DIM
from repro.dataflow.simulator import ClusterSim
from repro.dataflow.workloads import JOBS

RNG = np.random.RandomState(0)


def _ctx(i):
    return np.tanh(np.random.RandomState(300 + i).randn(CTX_DIM)
                   ).astype(np.float32)


def _nodes(k, a, z, observe=True, slow=1.0):
    nodes = []
    for i in range(3):
        s = a if i == 0 else z
        rt = slow * (20.0 / z + 0.5) if observe else None
        met = np.array([0.6, 1.0 / z, 0.2, 0.08, 0.0],
                       np.float32) if observe else None
        nodes.append(NodeAttrs(f"st{i}", _ctx(i), met, s, z, 1.0, rt))
    return nodes


def _graph(nodes, preds, k):
    n = len(nodes)
    edges = [(i, i + 1) for i in range(n - 1)] + \
        [(n + j, 0) for j in range(len(preds))]
    return build_graph(nodes + preds, edges, k)


@pytest.fixture(scope="module")
def trained_scaler():
    trainer = EnelTrainer(seed=0)
    scaler = EnelScaler(trainer, (4, 36))
    graphs = []
    for _ in range(8):
        for k in range(6):
            s = int(RNG.choice([4, 8, 16, 24, 32, 36]))
            nodes = _nodes(k, s, s)
            preds = []
            if k > 0:
                h = historical_summary(scaler.hist_summaries.get(k - 1, []),
                                       float(s))
                if h is not None:
                    preds.append(h)
            graphs.append(_graph(nodes, preds, k))
            scaler.record_component(k, nodes, sum(n.runtime for n in nodes))
    trainer.fit(graphs, steps=256, from_scratch=True)
    return scaler


def test_recommendation_scales_out_for_tight_targets(trained_scaler):
    builder = lambda k, a, z, preds: _graph(_nodes(k, a, z, observe=False),
                                            preds, k)
    # tight target -> large scale-out; loose target -> small scale-out
    s_tight, _, _ = trained_scaler.recommend(
        graph_builder=builder, next_comp=2, n_components=6, elapsed=10.0,
        current_scaleout=8, target_runtime=10.0 + 4 * (20 / 30 + 1.5))
    s_loose, _, _ = trained_scaler.recommend(
        graph_builder=builder, next_comp=2, n_components=6, elapsed=10.0,
        current_scaleout=8, target_runtime=10.0 + 4 * (20 / 5 + 1.5))
    assert s_tight > s_loose, (s_tight, s_loose)


def test_totals_monotone_decreasing_in_scaleout(trained_scaler):
    builder = lambda k, a, z, preds: _graph(_nodes(k, a, z, observe=False),
                                            preds, k)
    _, _, totals = trained_scaler.recommend(
        graph_builder=builder, next_comp=1, n_components=6, elapsed=0.0,
        current_scaleout=16, target_runtime=1.0)
    lo = np.mean([totals[s] for s in (4, 5, 6)])
    hi = np.mean([totals[s] for s in (32, 34, 36)])
    assert lo > hi               # ground truth is 1/z-dominated


def test_dynamic_scaling_beats_static_under_failures():
    """The whole point of the paper: reacting beats a fixed allocation when
    the environment degrades (failures slow the job down)."""
    job = JOBS["kmeans"]

    def run(scale_fn, seed):
        sim = ClusterSim(seed=seed)
        clock = 0.0
        s_prev = s = 12
        for k in range(job.n_components):
            comp = sim.run_component(job, k, clock=clock, start_scaleout=s_prev,
                                     end_scaleout=s, inject_failures=True,
                                     failures_log=[])
            clock += comp.runtime
            s_prev = s
            s = scale_fn(k, s)
        return clock

    static = np.mean([run(lambda k, s: s, i) for i in range(3)])
    # "oracle reaction": scale out hard after the first component
    reactive = np.mean([run(lambda k, s: 32, i) for i in range(3)])
    assert reactive < static
