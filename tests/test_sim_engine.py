"""Vectorized sim engine vs numpy reference: bit-parity, seeded failure
injector, noise-stream discipline."""
import numpy as np
import pytest

from repro.dataflow.simulator import ClusterSim, RETRY_PENALTY
from repro.dataflow.workloads import JOBS, StageSpec
from repro.sim.engine import (BatchedClusterSim, NumpySimBackend,
                              SimStepRequest)
from repro.sim.scenarios import make_scenario
from repro.sim.tables import F32, N_NOISE, R_MAX, W_MAX


def _assert_same_component(cn, cb, ctx=""):
    for sn, sb in zip(cn.stages, cb.stages):
        assert np.float32(sn.start) == np.float32(sb.start), (ctx, sn.name)
        assert np.float32(sn.runtime) == np.float32(sb.runtime), \
            (ctx, sn.name, sn.runtime, sb.runtime)
        assert sn.start_scaleout == sb.start_scaleout
        assert sn.end_scaleout == sb.end_scaleout
        assert sn.failures == sb.failures, (ctx, sn.name)
        np.testing.assert_array_equal(sn.metrics, sb.metrics, err_msg=ctx)


def _run_pair(npb, bb, jobs, n_runs=2, inject=True, seed=123):
    """Drive both backends through identical schedules; assert records are
    bit-identical (runtimes, metrics, failures, clocks)."""
    rng = np.random.RandomState(seed)
    for r in range(n_runs):
        for j in range(len(jobs)):
            npb.begin_run(j)
            bb.begin_run(j)
        clocks = [0.0] * len(jobs)
        s_prev = [int(rng.choice([8, 16, 33]))] * len(jobs)
        s_cur = list(s_prev)
        c_max = max(job.n_components for job in jobs)
        for k in range(c_max):
            idxs = [j for j, job in enumerate(jobs)
                    if k < job.n_components]
            reqs_n = [SimStepRequest(j, k, s_prev[j], s_cur[j], clocks[j],
                                     inject) for j in idxs]
            reqs_b = [SimStepRequest(j, k, s_prev[j], s_cur[j], clocks[j],
                                     inject) for j in idxs]
            res_n = npb.step(reqs_n)
            res_b = bb.step(reqs_b)          # ONE dispatch for all jobs
            for j, rn, rb in zip(idxs, res_n, res_b):
                ctx = f"run={r} comp={k} job={jobs[j].name}"
                _assert_same_component(rn.component, rb.component, ctx)
                assert rn.failures == rb.failures, ctx
                assert np.float32(rn.clock_end) == np.float32(rb.clock_end)
                clocks[j] = rb.clock_end
                s_prev[j] = s_cur[j]
                s_cur[j] = int(rng.choice([4, 8, 16, 24, 36]))


def test_engine_bit_parity_batch1_all_jobs():
    """Acceptance: batched engine == numpy reference bit-for-bit at batch=1
    on all 4 jobs (seeded, failures injected, random rescale schedules)."""
    for i, key in enumerate(("lr", "mpc", "kmeans", "gbt")):
        sc = make_scenario("node_failure", seed=3)
        npb, bb = NumpySimBackend(), BatchedClusterSim()
        npb.register(JOBS[key], seed=40 + i, scenario=sc)
        bb.register(JOBS[key], seed=40 + i, scenario=sc)
        _run_pair(npb, bb, [JOBS[key]], n_runs=2, seed=7 + i)


def test_engine_bit_parity_fleet_mixed_scenarios():
    """One batched backend, four jobs, four DIFFERENT scenarios riding the
    same dispatches — still bit-identical to four sequential numpy sims."""
    combos = [("lr", "stragglers"), ("mpc", "interference_burst"),
              ("kmeans", "spot_preemption"), ("gbt", "data_skew_drift")]
    npb, bb = NumpySimBackend(), BatchedClusterSim()
    jobs = []
    for i, (key, scn) in enumerate(combos):
        sc = make_scenario(scn, seed=5)
        npb.register(JOBS[key], seed=60 + i, scenario=sc)
        bb.register(JOBS[key], seed=60 + i, scenario=sc)
        jobs.append(JOBS[key])
    _run_pair(npb, bb, jobs, n_runs=2, seed=11)


def test_run_full_matches_stepped_reference():
    """Whole-run single-dispatch path == per-component numpy event loop."""
    jobs = [JOBS[k] for k in ("kmeans", "gbt", "kmeans")]
    sc = make_scenario("node_failure", seed=2)
    npb, bb = NumpySimBackend(), BatchedClusterSim()
    for i, job in enumerate(jobs):
        npb.register(job, seed=80 + i, scenario=sc)
        bb.register(job, seed=80 + i, scenario=sc)
    rng = np.random.RandomState(1)
    c_max = max(j.n_components for j in jobs)
    a = rng.choice([8, 16, 24], (len(jobs), c_max)).astype(np.int32)
    z = rng.choice([8, 16, 24, 36], (len(jobs), c_max)).astype(np.int32)
    full = bb.run_full(a, z, inject_failures=True)
    for j, job in enumerate(jobs):
        npb.begin_run(j)
        clock, fails = 0.0, []
        for c in range(job.n_components):
            r = npb.step([SimStepRequest(j, c, int(a[j, c]), int(z[j, c]),
                                         clock, True)])[0]
            clock = r.clock_end
            fails.extend(r.failures)
            _assert_same_component(r.component, full[j][0][c],
                                   f"job {j} comp {c}")
        assert fails == full[j][1]


# --------------------------------------------------- failure injector (bugfix)
def test_kill_seconds_come_from_per_window_table():
    """The injector draws ONE seeded kill second per (run, window) — every
    observed failure must equal a kill_time table entry of its window, and
    a window can kill at most once per run."""
    sim = ClusterSim(seed=9, scenario=make_scenario("node_failure", seed=0))
    job = JOBS["lr"]
    sim.begin_run()
    kill_row = sim._win["kill_time"][sim.run_idx % R_MAX]
    log = []
    clock = 0.0
    for k in range(job.n_components):
        comp = sim.run_component(job, k, clock=clock, start_scaleout=16,
                                 end_scaleout=16, inject_failures=True,
                                 failures_log=log)
        clock = comp.stages[-1].start + comp.stages[-1].runtime
    assert log, "a multi-window run at z=16 must observe kills"
    windows = [int(t // 90.0) for t in log]
    assert len(set(windows)) == len(windows), "a window killed twice"
    for t, w in zip(log, windows):
        assert np.float32(t) == kill_row[min(w, W_MAX - 1)]


def test_adjacent_stages_agree_on_window_kill():
    """Regression for the per-run draw bug: two stages overlapping the same
    window see the SAME kill second, so exactly one of them records it."""
    sim = ClusterSim(seed=4, scenario=make_scenario("node_failure", seed=0))
    spec = StageSpec("half", 46.0, 0.0, 0.0)     # ~46s: two stages span w0
    log = []
    clock = np.float32(0.0)
    recs = []
    for _ in range(4):                           # covers windows 0..1+
        rec = sim.run_stage(spec, start_scaleout=8, end_scaleout=8,
                            clock=clock, rescale_overhead=0.0,
                            inject_failures=True, failures_log=log)
        recs.append(rec)
        clock = rec.start + rec.runtime
    windows = [int(t // 90.0) for t in log]
    assert len(set(windows)) == len(windows)
    # every fully-covered window fired exactly once
    n_windows = int(clock // 90.0)
    assert len(log) >= n_windows


def test_failure_injection_determinism():
    """Same seeds -> identical failure trajectories (and different run
    indices -> different kill rows)."""
    def failures(seed):
        sim = ClusterSim(seed=seed,
                         scenario=make_scenario("node_failure", seed=1))
        out = []
        for _ in range(2):
            sim.begin_run()
            log = []
            clock = 0.0
            for k in range(JOBS["kmeans"].n_components):
                comp = sim.run_component(JOBS["kmeans"], k, clock=clock,
                                         start_scaleout=24, end_scaleout=24,
                                         inject_failures=True,
                                         failures_log=log)
                clock = comp.stages[-1].start + comp.stages[-1].runtime
            out.append(tuple(log))
        return out

    a, b = failures(5), failures(5)
    assert a == b
    assert a[0] != a[1], "per-run kill rows must differ"


def test_noise_stream_layout():
    """A run's noise block drawn upfront equals the reference's sequential
    per-stage draws (the property the batched engine relies on)."""
    r1 = np.random.RandomState(0)
    seq = np.stack([r1.randn(N_NOISE) for _ in range(10)])
    r2 = np.random.RandomState(0)
    block = r2.randn(10 * N_NOISE).reshape(10, N_NOISE)
    np.testing.assert_array_equal(seq, block)


def test_retry_penalty_charged_per_failure():
    sim = ClusterSim(seed=0, scenario=make_scenario("node_failure", seed=0))
    spec = StageSpec("long", 250.0, 0.0, 0.0)
    log = []
    rec = sim.run_stage(spec, start_scaleout=8, end_scaleout=8,
                        clock=0.0, rescale_overhead=0.0,
                        inject_failures=True, failures_log=log)
    assert rec.failures >= 2                    # windows 0 and 1 covered
    assert rec.runtime > 250.0 + RETRY_PENALTY * rec.failures * 0.5
