"""Enel model unit tests: eq.5 critical-path accumulation, eq.6 softmax
normalization, parameter budget, training convergence, scale-out sensitivity
through summary-node propagation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import forward_batch as forward, init_enel, n_params
from repro.core.graph import (CTX_DIM, MAX_NODES, N_METRICS, NodeAttrs,
                              build_graph, historical_summary, stack_graphs,
                              summary_node)
from repro.core.training import EnelTrainer

KEY = jax.random.PRNGKey(0)
RNG = np.random.RandomState(0)


def _node(name, rt=None, s=8.0, summary=False, metrics=True):
    return NodeAttrs(
        name=name, context=RNG.randn(CTX_DIM).astype(np.float32),
        metrics=RNG.rand(N_METRICS).astype(np.float32) if metrics else None,
        start_scaleout=s, end_scaleout=s, time_fraction=1.0, runtime=rt,
        is_summary=summary)


def _to_batch(g):
    return {k: jnp.asarray(v) for k, v in stack_graphs([g]).items()}


def test_param_budget_close_to_paper():
    p = init_enel(KEY)
    n = n_params(p)
    assert 4000 <= n <= 7000, n     # paper: 5155


def test_edge_weights_normalized():
    g = build_graph([_node("a"), _node("b"), _node("c")],
                    [(0, 2), (1, 2)])
    out = forward(init_enel(KEY), _to_batch(g))
    e = np.asarray(out["edges"])[0]
    np.testing.assert_allclose(e[2].sum(), 1.0, atol=1e-5)  # two preds
    assert e[0].sum() == 0 and e[1].sum() == 0              # roots: none


def test_eq5_critical_path_diamond():
    """tt(last) = t(last) + max over branches (diamond DAG)."""
    nodes = [_node(c) for c in "abcd"]
    g = build_graph(nodes, [(0, 1), (0, 2), (1, 3), (2, 3)])
    out = forward(init_enel(KEY), _to_batch(g))
    t = np.asarray(out["runtime"])[0]
    tt = np.asarray(out["acc_runtime"])[0]
    np.testing.assert_allclose(tt[0], t[0], rtol=1e-5)
    np.testing.assert_allclose(tt[3],
                               t[3] + max(t[1] + t[0], t[2] + t[0]),
                               rtol=1e-4)
    np.testing.assert_allclose(out["total_runtime"][0], tt.max(), rtol=1e-5)


def test_summary_nodes_excluded_from_runtime():
    nodes = [_node("a"), _node("b"), _node("P", summary=True)]
    g = build_graph(nodes, [(0, 1), (2, 0)])
    out = forward(init_enel(KEY), _to_batch(g))
    tt = np.asarray(out["acc_runtime"])[0]
    assert tt[2] == 0.0                          # summary carries no runtime
    t = np.asarray(out["runtime"])[0]
    np.testing.assert_allclose(tt[1], t[0] + t[1], rtol=1e-4)


def test_training_converges_and_is_scaleout_sensitive():
    def mk(k, s, observe=True):
        nodes = []
        for i in range(4):
            ctx = np.tanh(np.random.RandomState(50 + i).randn(CTX_DIM)
                          ).astype(np.float32)
            rt = (8.0 / s + 0.4 * i) if observe else None
            met = np.array([0.5, 1.0 / s, 0.2, 0.1, 0.0],
                           np.float32) if observe else None
            nodes.append(NodeAttrs(f"st{i}", ctx, met, s, s, 1.0, rt))
        return nodes

    hist = {k: [] for k in range(4)}
    graphs = []
    for _ in range(6):
        for k in range(4):
            s = float(RNG.choice([4, 8, 16, 32]))
            nodes = mk(k, s)
            preds = []
            h = historical_summary(hist[k], s)
            if h is not None:
                preds.append(h)
            n = len(nodes)
            edges = [(i, i + 1) for i in range(n - 1)] + \
                [(n + j, 0) for j in range(len(preds))]
            graphs.append(build_graph(nodes + preds, edges, k))
            hist[k].append(summary_node(nodes, f"P{k}"))
    tr = EnelTrainer(seed=1)
    l_start = tr.fit(graphs, steps=8)
    l_end = tr.fit(graphs, steps=256, from_scratch=True)
    assert l_end < l_start * 0.5

    def unobserved(s):
        nodes = mk(0, s, observe=False)
        h = historical_summary(hist[0], s)
        n = len(nodes)
        edges = [(i, i + 1) for i in range(n - 1)] + [(n, 0)]
        return build_graph(nodes + [h], edges, 0)

    p4, p32 = tr.predict([unobserved(4.0), unobserved(32.0)])
    assert p4 > p32, (p4, p32)    # more executors -> faster


def test_trainer_predict_matches_bucketing():
    tr = EnelTrainer(seed=0)
    g = build_graph([_node("a", rt=1.0)], [])
    one = tr.predict([g])
    three = tr.predict([g, g, g])
    np.testing.assert_allclose(one[0], three[0], rtol=1e-5)
    np.testing.assert_allclose(three[0], three[2], rtol=1e-5)
