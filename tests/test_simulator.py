"""Dataflow simulator properties: Ernest scaling, failure-injection rules,
rescale overhead accounting, dataset generators."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dependency")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dataflow.simulator import (FAILURE_WINDOW, ClusterSim,
                                      rescale_overhead)
from repro.dataflow.workloads import (DATASETS, JOBS, make_multiclass,
                                      make_points, make_vandermonde)


def test_jobs_match_table2():
    assert JOBS["lr"].iterations == 20
    assert JOBS["mpc"].iterations == 20
    assert "4 layers" in JOBS["mpc"].params
    assert JOBS["kmeans"].iterations == 10
    assert JOBS["kmeans"].dataset.name == "Points"
    assert JOBS["gbt"].dataset.name == "Vandermonde"
    assert DATASETS["multiclass"].size_gb == 27.0
    assert DATASETS["points"].size_gb == 48.0
    assert DATASETS["vandermonde"].size_gb == 35.0
    # GBT decomposes into more stages per iteration than the others (Fig. 5)
    assert len(JOBS["gbt"].iter_stages) > len(JOBS["lr"].iter_stages)


def test_datasets_generators():
    x, y = make_multiclass(256)
    assert x.shape == (256, 200) and set(np.unique(y)) <= {0, 1, 2}
    xv, yv = make_vandermonde(128)
    assert xv.shape == (128, 19)
    pts = make_points(512)
    assert pts.shape == (512, 2)


@pytest.mark.parametrize("job", ["lr", "mpc", "kmeans", "gbt"])
def test_runtime_decreases_with_scaleout(job):
    spec = JOBS[job]
    assert spec.base_runtime(8) > spec.base_runtime(32)


def test_mean_simulated_runtime_tracks_ground_truth():
    sim = ClusterSim(seed=0, interference_scale=0.0)
    job = JOBS["kmeans"]
    runs = []
    for _ in range(5):
        total = 0.0
        clock = 0.0
        for k in range(job.n_components):
            comp = sim.run_component(job, k, clock=clock, start_scaleout=16,
                                     end_scaleout=16, inject_failures=False,
                                     failures_log=[])
            total += comp.runtime
            clock += comp.runtime
        runs.append(total)
    assert abs(np.mean(runs) - job.base_runtime(16)) / job.base_runtime(16) < 0.15


def test_failures_only_above_four_executors():
    sim = ClusterSim(seed=1)
    job = JOBS["lr"]
    log4, log16 = [], []
    for k in range(job.n_components):
        sim.run_component(job, k, clock=k * 100.0, start_scaleout=4,
                          end_scaleout=4, inject_failures=True,
                          failures_log=log4)
        sim.run_component(job, k, clock=k * 100.0, start_scaleout=16,
                          end_scaleout=16, inject_failures=True,
                          failures_log=log16)
    assert len(log4) == 0                      # paper: only while > 4 alive
    assert len(log16) > 0


def test_failures_slow_down_runs():
    def total(inject, seed):
        sim = ClusterSim(seed=seed)
        job = JOBS["kmeans"]
        clock, tot = 0.0, 0.0
        for k in range(job.n_components):
            c = sim.run_component(job, k, clock=clock, start_scaleout=24,
                                  end_scaleout=24, inject_failures=inject,
                                  failures_log=[])
            tot += c.runtime
            clock += c.runtime
        return tot

    normal = np.mean([total(False, s) for s in range(4)])
    failed = np.mean([total(True, s) for s in range(4)])
    assert failed > normal * 1.02


@given(st.integers(4, 36), st.integers(4, 36))
@settings(max_examples=40, deadline=None)
def test_rescale_overhead_properties(a, z):
    o = rescale_overhead(a, z)
    if a == z:
        assert o == 0.0
    else:
        assert o >= rescale_overhead(a, a + 1 if a < 36 else a - 1) or \
            abs(z - a) <= 1
        assert o == rescale_overhead(z, a)      # symmetric


def test_rescale_charged_to_first_stage():
    sim = ClusterSim(seed=2, interference_scale=0.0)
    comp = sim.run_component(JOBS["lr"], 1, clock=0.0, start_scaleout=8,
                             end_scaleout=16, inject_failures=False,
                             failures_log=[])
    assert comp.stages[0].overhead > 0
    assert all(s.overhead == 0 for s in comp.stages[1:])
    assert comp.stages[0].start_scaleout == 8
    assert comp.stages[0].end_scaleout == 16


def test_metrics_bounded():
    sim = ClusterSim(seed=3)
    comp = sim.run_component(JOBS["mpc"], 2, clock=0.0, start_scaleout=12,
                             end_scaleout=12, inject_failures=False,
                             failures_log=[])
    for st_ in comp.stages:
        assert st_.metrics.shape == (5,)
        assert np.all(np.isfinite(st_.metrics))
        assert st_.metrics[0] <= 1.0 and st_.metrics[0] >= 0.0
