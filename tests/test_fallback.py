"""Decision guardrails + service fault envelope.

Contracts under test:

* the model-free :class:`FallbackPolicy` ALWAYS answers with one of the
  real candidates, for arbitrary finite/non-finite prediction vectors,
  elapsed times and targets (property-tested when hypothesis is
  available, seeded-sweep otherwise);
* NaN-poisoned model parameters trip the on-device guardrail and the
  service answers from the fallback policy — never a non-finite pick;
* exhausted dispatch retries degrade a whole group to fallback decisions
  and feed the circuit breaker through its CLOSED -> OPEN -> HALF_OPEN ->
  CLOSED lifecycle;
* overload shedding rejects best-effort requests first and the shed
  answers are bounded too.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fallback import FallbackPolicy
from repro.core.service import (CircuitBreaker, DecisionService,
                                DispatchFault, DispatchTimeout)
from repro.dataflow import JobExperiment
from repro.dataflow.runner import _component_nodes, _future_nodes, _to_graph
from repro.core.graph import summary_node

CANDS = [4, 8, 12, 16, 24, 36]
WEIRD = [float("nan"), float("inf"), float("-inf"), -1e30, 0.0, 1e30, 7.5]


# ------------------------------------------------------- policy bounds
def test_fallback_clamp_always_a_candidate():
    pol = FallbackPolicy()
    rng = np.random.RandomState(0)
    for _ in range(300):
        cur = float(rng.choice(WEIRD + [4, 9, 36, 100, -3]))
        elapsed = float(rng.choice(WEIRD))
        target = float(rng.choice(WEIRD))
        s = pol.clamp(CANDS, cur, elapsed, target)
        assert s in CANDS


def test_fallback_decide_always_a_candidate_with_garbage_totals():
    pol = FallbackPolicy()
    rng = np.random.RandomState(1)
    for _ in range(300):
        totals = [float(rng.choice(WEIRD)) for _ in CANDS]
        s, pred = pol.decide(CANDS, totals, current=int(rng.choice(CANDS)),
                             elapsed=float(rng.choice(WEIRD)),
                             target=float(rng.choice(WEIRD)))
        assert s in CANDS
        finite = {c: t for c, t in zip(CANDS, totals) if math.isfinite(t)}
        if finite:
            assert math.isfinite(pred)      # salvage path used a real pred
        else:
            assert math.isnan(pred)         # blind clamp: no prediction


def test_fallback_salvage_prefers_smallest_compliant():
    pol = FallbackPolicy()
    totals = {4: float("nan"), 8: 50.0, 12: 30.0, 16: 20.0, 24: 25.0}
    s, pred = pol.decide([4, 8, 12, 16, 24], totals, current=8,
                         elapsed=10.0, target=31.0)
    assert (s, pred) == (12, 30.0)          # smallest finite compliant
    # nothing compliant -> least violating finite
    s, pred = pol.decide([4, 8, 12, 16, 24], totals, current=8,
                         elapsed=10.0, target=5.0)
    assert (s, pred) == (16, 20.0)


def test_fallback_urgency_steps_are_bounded():
    pol = FallbackPolicy(max_step=4)
    assert pol.clamp(CANDS, 8, elapsed=1.0, target=100.0) == 8    # no rush
    assert pol.clamp(CANDS, 8, elapsed=60.0, target=100.0) == 12  # half step
    assert pol.clamp(CANDS, 8, elapsed=95.0, target=100.0) == 12  # full step
    assert pol.clamp(CANDS, 36, elapsed=95.0, target=100.0) == 36  # capped


def test_fallback_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    anyfloat = st.floats(allow_nan=True, allow_infinity=True, width=32)

    @hyp.given(
        cands=st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                       max_size=8, unique=True),
        totals=st.lists(anyfloat, min_size=8, max_size=8),
        current=anyfloat, elapsed=anyfloat, target=anyfloat)
    @hyp.settings(max_examples=200, deadline=None)
    def check(cands, totals, current, elapsed, target):
        pol = FallbackPolicy()
        s, _ = pol.decide(cands, totals[:len(cands)], current=current,
                          elapsed=elapsed, target=target)
        assert s in set(int(c) for c in cands)
        assert min(cands) <= s <= max(cands)

    check()


# --------------------------------------------------- service-level fixtures
@pytest.fixture(scope="module")
def profiled_exp():
    exp = JobExperiment("kmeans", seed=31)
    exp.profile(2)
    return exp


def _request(exp, seed=500):
    job = exp.job
    builder = lambda ci, a, z, pr: _to_graph(
        _future_nodes(exp.encoder, job, ci, a, z), pr, ci)
    comp = exp.sim.run_component(job, 0, clock=0.0, start_scaleout=8,
                                 end_scaleout=8, inject_failures=False,
                                 failures_log=[])
    summ = summary_node(_component_nodes(exp.encoder, job, comp), name="P0")
    exp.encoder.rng = np.random.RandomState(seed)
    return exp.enel.prepare_request(
        graph_builder=builder, next_comp=1, n_components=job.n_components,
        elapsed=comp.runtime, current_scaleout=8,
        target_runtime=exp.target, current_summary=summ)


# ------------------------------------------------ guardrail: poisoned model
def test_guardrail_nan_params_falls_back(profiled_exp):
    import dataclasses
    exp = profiled_exp
    req = _request(exp)
    bad = jax.tree_util.tree_map(lambda p: jnp.full_like(p, jnp.nan),
                                 req.params)
    svc = DecisionService()
    req_bad = dataclasses.replace(req, params=bad)
    res = svc.decide([req_bad])[0]
    assert res.fallback
    assert res.scaleout in req.candidate_list
    assert svc.guardrail_trips == 1 and svc.fallback_decisions == 1
    # the same request with healthy params is a model decision again
    res2 = svc.decide([req])[0]
    assert not res2.fallback and math.isfinite(res2.predicted)


# ------------------------------- retry exhaustion + circuit breaker lifecycle
def test_retries_exhausted_fallback_and_breaker(profiled_exp):
    class AlwaysDown:
        def __call__(self):
            raise DispatchTimeout("injected")

    svc = DecisionService(max_retries=1, backoff_base_s=0.0,
                          breaker_threshold=2, breaker_probe_after=2)
    svc.fault_injector = AlwaysDown()
    req = _request(profiled_exp)
    r1 = svc.decide([req])[0]
    assert r1.fallback and r1.scaleout in req.candidate_list
    assert svc.retries == 1 and svc.dispatch_failures == 2
    assert svc.breaker.state == CircuitBreaker.CLOSED
    r2 = svc.decide([req])[0]               # second failure trips (thr 2)
    assert r2.fallback
    assert svc.breaker.state == CircuitBreaker.OPEN
    assert svc.breaker_trips == 1
    # open breaker: no dispatch attempts at all, straight to fallback
    before = svc.dispatch_failures
    r3 = svc.decide([req])[0]
    assert r3.fallback and svc.dispatch_failures == before
    # after probe_after blocked calls the breaker half-opens; a healthy
    # probe dispatch closes it again
    r4 = svc.decide([req])[0]
    assert r4.fallback
    assert svc.breaker.state == CircuitBreaker.HALF_OPEN
    svc.fault_injector = None
    r5 = svc.decide([req])[0]
    assert not r5.fallback
    assert svc.breaker.state == CircuitBreaker.CLOSED


def test_circuit_breaker_unit_lifecycle():
    br = CircuitBreaker(threshold=3, probe_after=2)
    for _ in range(2):
        assert br.allow()
        br.record(False)
    assert br.state == CircuitBreaker.CLOSED
    assert br.allow()
    br.record(False)                        # third consecutive failure
    assert br.state == CircuitBreaker.OPEN and br.trips == 1
    assert not br.allow()                   # blocked
    assert not br.allow()                   # blocked, then half-open
    assert br.state == CircuitBreaker.HALF_OPEN
    assert br.allow()                       # the probe
    br.record(False)                        # probe failed: re-open
    assert br.state == CircuitBreaker.OPEN and br.trips == 2
    br._blocked_calls = br.probe_after
    assert not br.allow()
    assert br.allow()
    br.record(True)                         # probe succeeded
    assert br.state == CircuitBreaker.CLOSED
    # snapshot/restore round-trips the full lifecycle state
    st = br.snapshot()
    br2 = CircuitBreaker()
    br2.restore(st)
    assert br2.snapshot() == st


# ----------------------------------------------------------- load shedding
def test_overload_sheds_best_effort_first(profiled_exp):
    svc = DecisionService(shed_capacity=1)
    req_a = _request(profiled_exp, seed=600)
    req_b = _request(profiled_exp, seed=601)
    req_b.best_effort = True
    res_a, res_b = svc.decide([req_a, req_b])
    assert not res_a.shed and res_b.shed
    assert res_b.fallback
    assert res_b.scaleout in req_b.candidate_list
    assert svc.shed_requests == 1


def test_dispatch_fault_hierarchy():
    assert issubclass(DispatchTimeout, DispatchFault)
    assert issubclass(DispatchFault, RuntimeError)
