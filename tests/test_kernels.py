"""Pallas kernel sweeps (shapes x dtypes) vs pure-jnp oracles, plus the
models/ssm chunkwise scan vs the fully-recurrent oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ops import decode_attn
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.mlstm_chunk.ops import mlstm
from repro.kernels.mlstm_chunk.ref import mlstm_recurrent_ref

RNG = np.random.RandomState(0)


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 3e-2


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kh,d,causal,win,cap",
    [(2, 128, 4, 2, 32, True, 0, 0.0),
     (1, 256, 4, 4, 64, True, 64, 50.0),
     (2, 96, 8, 2, 32, False, 0, 0.0),      # padded, non-causal
     (1, 64, 2, 1, 128, True, 32, 0.0),
     (1, 192, 6, 3, 32, True, 0, 30.0)])
def test_flash_attention_sweep(b, s, h, kh, d, causal, win, cap, dtype):
    q = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    k = jnp.asarray(RNG.randn(b, s, kh, d), dtype)
    v = jnp.asarray(RNG.randn(b, s, kh, d), dtype)
    out = mha(q, k, v, causal=causal, window=win, softcap=cap,
              block_q=64, block_k=64)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=win,
        softcap=cap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kh,d,pos,win",
    [(2, 256, 4, 2, 32, 100, 0),
     (1, 512, 8, 8, 64, 511, 128),
     (2, 128, 4, 1, 32, 0, 0),
     (1, 128, 2, 2, 128, 64, 32)])
def test_flash_decode_sweep(b, s, h, kh, d, pos, win, dtype):
    q = jnp.asarray(RNG.randn(b, 1, h, d), dtype)
    ck = jnp.asarray(RNG.randn(b, s, kh, d), dtype)
    cv = jnp.asarray(RNG.randn(b, s, kh, d), dtype)
    out = decode_attn(q, ck, cv, jnp.int32(pos), window=win, block_k=64)
    ref = decode_ref(q[:, 0], ck.transpose(0, 2, 1, 3),
                     cv.transpose(0, 2, 1, 3), jnp.int32(pos),
                     window=win)[:, None]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("b,s,h,d,chunk",
                         [(2, 128, 2, 32, 32), (1, 256, 4, 64, 64),
                          (1, 64, 1, 128, 16)])
def test_mlstm_chunk_sweep(b, s, h, d, chunk, dtype):
    q = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    k = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    v = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    ig = jnp.asarray(RNG.randn(b, s, h), jnp.float32)
    fg = jnp.asarray(RNG.randn(b, s, h) + 2, jnp.float32)
    out = mlstm(q, k, v, ig, fg, chunk=chunk)
    ref = mlstm_recurrent_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), ig.transpose(0, 2, 1),
        fg.transpose(0, 2, 1)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-4, rtol=2e-4)


def test_model_mlstm_scan_matches_recurrent_oracle():
    """models/ssm.mlstm_chunk_scan implements the same math as the kernel."""
    from repro.models.ssm import mlstm_chunk_scan
    b, s, h, d = 1, 64, 2, 16
    q = jnp.asarray(RNG.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(RNG.randn(b, s, h, d), jnp.float32) / np.sqrt(d)
    v = jnp.asarray(RNG.randn(b, s, h, d), jnp.float32)
    ig = jnp.asarray(RNG.randn(b, s, h), jnp.float32)
    f_pre = jnp.asarray(RNG.randn(b, s, h) + 2, jnp.float32)
    lf = jax.nn.log_sigmoid(f_pre)
    out, _ = mlstm_chunk_scan(q, k, v, ig, lf)
    ref = mlstm_recurrent_ref(
        q.transpose(0, 2, 1, 3),
        (k * np.sqrt(d)).transpose(0, 2, 1, 3),      # ref divides by sqrt(d)
        v.transpose(0, 2, 1, 3), ig.transpose(0, 2, 1),
        f_pre.transpose(0, 2, 1)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)
